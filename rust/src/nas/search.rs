//! Two-step greedy search (§3.4.2): hardware-optimize every sample, keep
//! the top-k by throughput, score accuracy, pick the best.

use super::space::{sample_network, SearchSpace};
use crate::events::{repr::histogram2_norm, DatasetProfile};
use crate::hwopt::{allocate, stats::collect_stats_for_profile, AllocResult, Budget};
use crate::model::exec::forward_f32_observed;
use crate::model::weights::FloatWeights;
use crate::model::NetworkSpec;
use crate::util::Rng;

/// Search configuration.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Architectures to sample (the paper samples "hundreds").
    pub n_samples: usize,
    /// Candidates kept for accuracy scoring.
    pub top_k: usize,
    /// Sparsity-statistics samples per architecture.
    pub n_stat_samples: usize,
    /// Probe-training set size per class.
    pub probe_per_class: usize,
    pub seed: u64,
    pub budget: Budget,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            n_samples: 40,
            top_k: 5,
            n_stat_samples: 4,
            probe_per_class: 12,
            seed: 0xE5DA,
            budget: Budget::zcu102(),
        }
    }
}

/// One evaluated architecture.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub spec: NetworkSpec,
    pub alloc: AllocResult,
    /// Estimated throughput (inferences/s at the paper's 187 MHz clock).
    pub throughput: f64,
    /// Accuracy proxy in [0, 1] (linear probe on random features); None
    /// until scored.
    pub accuracy: Option<f64>,
}

/// Pooled random-feature extraction for the probe.
fn pooled_features(
    spec: &NetworkSpec,
    w: &FloatWeights,
    input: &crate::sparse::SparseMap<f32>,
) -> Vec<f32> {
    let ops = spec.ops();
    let pool_idx = ops
        .iter()
        .position(|o| matches!(o, crate::model::Op::GlobalPool { .. }))
        .unwrap();
    let mut pooled: Vec<f32> = Vec::new();
    forward_f32_observed(spec, w, input, &mut |i, obs| {
        if i == pool_idx {
            if let crate::model::exec::Observed::VecF32(v) = obs {
                pooled = v.to_vec();
            }
        }
    });
    pooled
}

/// Linear-probe accuracy proxy: extract pooled features from the
/// random-weight network and train a softmax head with SGD; report held-out
/// accuracy. Fast, differentiable-free, and monotone with feature quality.
pub fn probe_accuracy(
    spec: &NetworkSpec,
    profile: &DatasetProfile,
    per_class: usize,
    seed: u64,
) -> f64 {
    let weights = FloatWeights::random(spec, seed);
    let mut rng = Rng::new(seed ^ 0x9E37);
    let n_classes = profile.n_classes;
    // Build train/test features.
    let make_set = |n: usize, rng: &mut Rng| -> Vec<(usize, Vec<f32>)> {
        let mut out = Vec::new();
        for class in 0..n_classes {
            for _ in 0..n {
                let es = profile.sample(class, rng);
                let m = histogram2_norm(&es, profile.w, profile.h, 8.0);
                out.push((class, pooled_features(spec, &weights, &m)));
            }
        }
        out
    };
    let train = make_set(per_class, &mut rng);
    let test = make_set((per_class / 3).max(1), &mut rng);
    let d = train[0].1.len();
    // Softmax regression, plain SGD.
    let mut wlin = vec![0f32; d * n_classes];
    let mut blin = vec![0f32; n_classes];
    let lr = 0.1f32;
    let mut order: Vec<usize> = (0..train.len()).collect();
    for _epoch in 0..30 {
        rng.shuffle(&mut order);
        for &i in &order {
            let (label, x) = &train[i];
            // logits
            let mut logits = blin.clone();
            for ci in 0..d {
                for co in 0..n_classes {
                    logits[co] += x[ci] * wlin[ci * n_classes + co];
                }
            }
            let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let exps: Vec<f32> = logits.iter().map(|&v| (v - maxl).exp()).collect();
            let z: f32 = exps.iter().sum();
            for co in 0..n_classes {
                let p = exps[co] / z;
                let g = p - if co == *label { 1.0 } else { 0.0 };
                blin[co] -= lr * g;
                for ci in 0..d {
                    wlin[ci * n_classes + co] -= lr * g * x[ci];
                }
            }
        }
    }
    let mut correct = 0usize;
    for (label, x) in &test {
        let mut logits = blin.clone();
        for ci in 0..d {
            for co in 0..n_classes {
                logits[co] += x[ci] * wlin[ci * n_classes + co];
            }
        }
        if crate::model::exec::argmax(&logits) == *label {
            correct += 1;
        }
    }
    correct as f64 / test.len() as f64
}

/// Run the full two-step search for a dataset profile.
pub fn search(profile: &DatasetProfile, space: &SearchSpace, cfg: &SearchConfig) -> Vec<Candidate> {
    let mut rng = Rng::new(cfg.seed);
    let mut candidates: Vec<Candidate> = Vec::new();
    // Step 1: sample + hardware-optimize.
    for i in 0..cfg.n_samples {
        let spec = sample_network(space, &mut rng, &format!("{}_cand{}", profile.name, i));
        let stats =
            collect_stats_for_profile(&spec, profile, cfg.n_stat_samples, cfg.seed ^ i as u64);
        if let Some(alloc) = allocate(&spec, &stats, &cfg.budget) {
            let throughput = crate::hwopt::power::CLOCK_HZ / alloc.latency.max(1.0);
            candidates.push(Candidate { spec, alloc, throughput, accuracy: None });
        }
    }
    // Step 2: top-k by throughput, then accuracy-score those.
    candidates.sort_by(|a, b| b.throughput.partial_cmp(&a.throughput).unwrap());
    candidates.truncate(cfg.top_k);
    for c in candidates.iter_mut() {
        c.accuracy = Some(probe_accuracy(&c.spec, profile, cfg.probe_per_class, cfg.seed));
    }
    // Best accuracy first (ties by throughput).
    candidates.sort_by(|a, b| {
        b.accuracy
            .partial_cmp(&a.accuracy)
            .unwrap()
            .then(b.throughput.partial_cmp(&a.throughput).unwrap())
    });
    candidates
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_returns_scored_feasible_candidates() {
        let profile = DatasetProfile::n_mnist();
        let space = SearchSpace::for_dataset(profile.w, profile.h, profile.n_classes);
        let cfg = SearchConfig {
            n_samples: 6,
            top_k: 2,
            n_stat_samples: 2,
            probe_per_class: 4,
            seed: 7,
            budget: Budget::zcu102(),
        };
        let out = search(&profile, &space, &cfg);
        assert!(!out.is_empty());
        assert!(out.len() <= 2);
        for c in &out {
            assert!(c.accuracy.is_some());
            assert!(c.throughput > 0.0);
            assert!(c.alloc.resources.dsp <= cfg.budget.dsp);
        }
        // Sorted by accuracy.
        for w in out.windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy);
        }
    }

    #[test]
    fn probe_beats_chance_on_separable_classes() {
        // With real (class-distinct) synthetic data even random conv
        // features + a linear head must beat chance on 3 classes.
        let profile = DatasetProfile::roshambo17();
        let spec = crate::model::NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
        let acc = probe_accuracy(&spec, &profile, 8, 3);
        assert!(acc > 1.0 / 3.0 + 0.1, "probe accuracy {acc} not above chance");
    }
}
