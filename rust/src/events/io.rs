//! Binary container for generated event datasets — the bridge from the
//! rust generator to the python training path (`esda gen-data` writes,
//! `python/compile/data.py` reads with `numpy.fromfile`).
//!
//! Layout (little-endian):
//! ```text
//! magic   u32 = 0x45534441 ("ESDA")
//! version u32 = 1
//! w, h    u32, u32
//! n       u32                     number of samples
//! then per sample:
//!   label    u32
//!   n_events u32
//!   events   n_events × { t_us u32, x u16, y u16, polarity u8, pad u8 }
//! ```

use super::aer::Event;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const MAGIC: u32 = 0x4553_4441;
pub const VERSION: u32 = 1;

/// One labelled recording.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub label: u32,
    pub events: Vec<Event>,
}

fn put_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
fn put_u16(w: &mut impl Write, v: u16) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
fn get_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn get_u16(r: &mut impl Read) -> std::io::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

/// Validate a count against the container's u32 fields. Every on-disk
/// count is u32; a plain `as u32` cast would silently truncate anything
/// larger and produce a file that *parses* — with the wrong shape.
fn count_u32(v: u64, what: &str) -> std::io::Result<u32> {
    u32::try_from(v).map_err(|_| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("{what} {v} exceeds the container's u32 limit ({})", u32::MAX),
        )
    })
}

/// Write the container header. For append-mode ("tail") files the writer
/// may not know the final sample count up front; `n` is then a lower
/// bound — `read_dataset` trusts it exactly, while a tailing reader
/// follows whatever samples actually appear.
pub fn write_header(f: &mut impl Write, w: usize, h: usize, n: usize) -> std::io::Result<()> {
    // Validate every count before the first byte goes out: a failed
    // header must not leave a partial prefix behind.
    let wv = count_u32(w as u64, "width")?;
    let hv = count_u32(h as u64, "height")?;
    let nv = count_u32(n as u64, "sample count")?;
    put_u32(f, MAGIC)?;
    put_u32(f, VERSION)?;
    put_u32(f, wv)?;
    put_u32(f, hv)?;
    put_u32(f, nv)
}

/// Serialize one sample (fixed prefix + events). Composable with
/// [`write_header`] for camera-dump pipelines that append samples to a
/// growing file a [`TailSource`](crate::coordinator::ingest::TailSource)
/// follows.
pub fn append_sample(f: &mut impl Write, s: &Sample) -> std::io::Result<()> {
    // Validate before emitting: a rejected sample leaves no partial
    // prefix in the (possibly live-tailed) file.
    let ne = count_u32(s.events.len() as u64, "sample event count")?;
    put_u32(f, s.label)?;
    put_u32(f, ne)?;
    for e in &s.events {
        put_u32(f, e.t_us)?;
        put_u16(f, e.x)?;
        put_u16(f, e.y)?;
        f.write_all(&[e.polarity as u8, 0u8])?;
    }
    Ok(())
}

/// Write a dataset file.
pub fn write_dataset(path: &Path, w: usize, h: usize, samples: &[Sample]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = BufWriter::new(File::create(path)?);
    write_header(&mut f, w, h, samples.len())?;
    for s in samples {
        append_sample(&mut f, s)?;
    }
    f.flush()
}

/// Bytes one serialized event occupies (t_us + x + y + polarity + pad).
pub(crate) const EVENT_BYTES: u64 = 10;
/// Bytes the fixed per-sample prefix occupies (label + n_events).
pub(crate) const SAMPLE_HEADER_BYTES: u64 = 8;
/// Bytes the file header occupies (magic + version + w + h + n).
pub(crate) const FILE_HEADER_BYTES: u64 = 20;
/// `Vec::with_capacity` clamp for header-supplied counts. Counts are
/// untrusted until the payload bytes actually arrive: a truncated or
/// corrupt file must not demand a multi-GB allocation up front. Reads
/// past the clamp grow the vec amortized as real bytes are decoded.
const MAX_PREALLOC: usize = 1 << 16;

fn invalid(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Widen an on-disk u32 count to usize, checked — a 16-bit target would
/// silently truncate under a bare `as usize`.
fn usize_of(v: u32, what: &str) -> std::io::Result<usize> {
    usize::try_from(v).map_err(|_| invalid(format!("{what} {v} exceeds usize on this target")))
}

/// Read and validate the file header, returning `(w, h, n)`.
pub(crate) fn read_file_header(f: &mut impl Read) -> std::io::Result<(usize, usize, usize)> {
    let magic = get_u32(f)?;
    if magic != MAGIC {
        return Err(invalid(format!("bad magic {magic:#x}")));
    }
    let version = get_u32(f)?;
    if version != VERSION {
        return Err(invalid(format!("unsupported version {version}")));
    }
    let w = usize_of(get_u32(f)?, "width")?;
    let h = usize_of(get_u32(f)?, "height")?;
    let n = usize_of(get_u32(f)?, "sample count")?;
    Ok((w, h, n))
}

/// Decode `ne` serialized events (the caller has already validated `ne`
/// against whatever byte budget applies).
pub(crate) fn read_events(f: &mut impl Read, ne: usize) -> std::io::Result<Vec<Event>> {
    let mut events = Vec::with_capacity(ne.min(MAX_PREALLOC));
    for _ in 0..ne {
        let t_us = get_u32(f)?;
        let x = get_u16(f)?;
        let y = get_u16(f)?;
        let mut pb = [0u8; 2];
        f.read_exact(&mut pb)?;
        events.push(Event { t_us, x, y, polarity: pb[0] != 0 });
    }
    Ok(events)
}

/// Read a dataset file. Returns (w, h, samples).
///
/// Header-supplied counts are validated against a running remaining-bytes
/// budget before any allocation sized from them: a sample claiming more
/// events than the *unconsumed* bytes could possibly hold (accounting for
/// the fixed prefixes every later sample still needs) is rejected as
/// corrupt instead of being trusted with a `Vec::with_capacity`
/// reservation. Checking each claim against the whole file size — as an
/// earlier revision did — lets several samples cumulatively over-claim
/// the file while each passes individually.
pub fn read_dataset(path: &Path) -> std::io::Result<(usize, usize, Vec<Sample>)> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut f = BufReader::new(file);
    let (w, h, n) = read_file_header(&mut f)?;
    // Bytes available past the file header; every claim draws on this.
    let mut remaining = file_len.saturating_sub(FILE_HEADER_BYTES);
    // Every sample needs at least its fixed prefix on disk.
    if (n as u64).saturating_mul(SAMPLE_HEADER_BYTES) > remaining {
        return Err(invalid(format!(
            "header claims {n} sample(s) but the file is only {file_len} byte(s)"
        )));
    }
    let mut samples = Vec::with_capacity(n.min(MAX_PREALLOC));
    for i in 0..n {
        if remaining < SAMPLE_HEADER_BYTES {
            return Err(invalid(format!("file truncated before sample {i}'s prefix")));
        }
        remaining -= SAMPLE_HEADER_BYTES;
        let label = get_u32(&mut f)?;
        let ne = usize_of(get_u32(&mut f)?, "event count")?;
        let need = (ne as u64).saturating_mul(EVENT_BYTES);
        // Later samples' fixed prefixes are spoken for: this sample's
        // events may only claim what's left after them.
        let later_prefixes = ((n - 1 - i) as u64) * SAMPLE_HEADER_BYTES;
        if need.saturating_add(later_prefixes) > remaining {
            return Err(invalid(format!(
                "sample {i} claims {ne} event(s) ({need} B) but only {remaining} byte(s) \
                 remain for it and {later_prefixes} B of later sample prefixes"
            )));
        }
        remaining -= need;
        samples.push(Sample { label, events: read_events(&mut f, ne)? });
    }
    Ok((w, h, samples))
}

/// Generate and write a full train/test dataset for a profile:
/// `n_per_class` train + `n_per_class_test` test samples per class.
/// Returns the two file paths.
pub fn generate_dataset_files(
    profile: &super::DatasetProfile,
    out_dir: &Path,
    n_per_class: usize,
    n_per_class_test: usize,
    seed: u64,
) -> std::io::Result<(std::path::PathBuf, std::path::PathBuf)> {
    let mut rng = crate::util::Rng::new(seed);
    let make = |n: usize, rng: &mut crate::util::Rng| -> Vec<Sample> {
        let mut out = Vec::new();
        for class in 0..profile.n_classes {
            for _ in 0..n {
                out.push(Sample {
                    // lint:allow(cast): class < n_classes, far below u32::MAX
                    label: class as u32,
                    events: profile.sample(class, rng),
                });
            }
        }
        out
    };
    let train = make(n_per_class, &mut rng);
    let test = make(n_per_class_test, &mut rng);
    let train_path = out_dir.join(format!("{}_train.esda", profile.name));
    let test_path = out_dir.join(format!("{}_test.esda", profile.name));
    write_dataset(&train_path, profile.w, profile.h, &train)?;
    write_dataset(&test_path, profile.w, profile.h, &test)?;
    Ok((train_path, test_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::DatasetProfile;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("esda_io_test");
        let path = dir.join("t.esda");
        let samples = vec![
            Sample {
                label: 3,
                events: vec![
                    Event { t_us: 10, x: 1, y: 2, polarity: true },
                    Event { t_us: 20, x: 3, y: 4, polarity: false },
                ],
            },
            Sample { label: 0, events: vec![] },
        ];
        write_dataset(&path, 64, 48, &samples).unwrap();
        let (w, h, back) = read_dataset(&path).unwrap();
        assert_eq!((w, h), (64, 48));
        assert_eq!(back, samples);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("esda_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.esda");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(read_dataset(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A corrupt header claiming astronomically many samples/events must be
    /// rejected from the file-size check, not trusted with a header-sized
    /// `Vec::with_capacity` (a truncated file could otherwise demand tens
    /// of GB before the first payload byte is read).
    #[test]
    fn rejects_truncated_file_without_header_sized_alloc() {
        let dir = std::env::temp_dir().join(format!("esda_io_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // Valid magic/version/geometry, but n = u32::MAX and no payload.
        let path = dir.join("huge_n.esda");
        let mut bytes = Vec::new();
        for v in [MAGIC, VERSION, 64, 48, u32::MAX] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let err = read_dataset(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert!(err.to_string().contains("sample"), "{err}");

        // One sample whose event count (~5 GB worth) exceeds the file size.
        let path = dir.join("huge_ne.esda");
        let mut bytes = Vec::new();
        for v in [MAGIC, VERSION, 64, 48, 1, /* label */ 0, /* n_events */ 0x2000_0000] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let err = read_dataset(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert!(err.to_string().contains("event"), "{err}");

        // A file truncated mid-events still errors (cleanly, via read_exact).
        let path = dir.join("cut.esda");
        let mut bytes = Vec::new();
        for v in [MAGIC, VERSION, 64, 48, 1, 0, 2] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[1, 2, 3]); // 3 of the 20 event bytes
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_dataset(&path).is_err());

        std::fs::remove_dir_all(&dir).ok();
    }

    /// Writer-side count validation (the "mocked-count" path: a real
    /// `Vec` of `u32::MAX + 1` events would need ~70 GB, so the check is
    /// exercised directly). Counts that fit the container's u32 fields
    /// pass; anything larger must fail with `InvalidInput` instead of
    /// silently truncating into a corrupt-but-parseable file.
    #[test]
    fn writer_rejects_counts_over_u32() {
        for ok in [0u64, 1, u32::MAX as u64] {
            assert_eq!(count_u32(ok, "samples").unwrap() as u64, ok);
        }
        for over in [u32::MAX as u64 + 1, u64::MAX] {
            let err = count_u32(over, "sample count").unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput, "{err}");
            assert!(err.to_string().contains("sample count"), "{err}");
        }
        // The same guard sits on the real writer path: a header claiming
        // an over-u32 width fails before any bytes are written.
        let mut sink = Vec::new();
        if usize::BITS > 32 {
            let too_wide = u32::MAX as u64 + 1;
            let err = write_header(&mut sink, too_wide as usize, 1, 0).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput, "{err}");
            assert!(sink.is_empty(), "failed header must not emit partial bytes");
        }
        write_header(&mut sink, 4, 4, 1).unwrap();
        assert_eq!(sink.len(), FILE_HEADER_BYTES as usize);
    }

    /// Regression: samples that *cumulatively* over-claim the file while
    /// each individually fits `file_len` must be rejected with
    /// `InvalidData` at the first over-claim — the old guard compared
    /// every claim against the whole file size, so the reader only
    /// noticed at an `UnexpectedEof` deep inside the payload (after
    /// honoring each claim with a prefix-sized preallocation).
    #[test]
    fn rejects_cumulative_overclaim_with_remaining_budget() {
        let dir = std::env::temp_dir().join(format!("esda_io_cum_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cumulative.esda");
        let mut bytes = Vec::new();
        // Header: 2 samples. Sample 0 claims 6 events (60 B, present).
        // Sample 1 claims 6 events again — individually under the 146-byte
        // file size, but only 10 payload bytes remain.
        for v in [MAGIC, VERSION, 8, 8, 2, /* label */ 0, /* ne */ 6] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 60]); // sample 0's events
        for v in [/* label */ 1u32, /* ne */ 6] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 10]); // 10 of the 60 claimed bytes
        std::fs::write(&path, &bytes).unwrap();
        let err = read_dataset(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert!(err.to_string().contains("sample 1"), "{err}");
        assert!(err.to_string().contains("remain"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The remaining-bytes budget reserves later samples' fixed prefixes:
    /// a first sample claiming every non-prefix byte of a two-sample file
    /// is an over-claim even though the bytes nominally exist.
    #[test]
    fn budget_reserves_later_sample_prefixes() {
        let dir = std::env::temp_dir().join(format!("esda_io_pfx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prefix.esda");
        let mut bytes = Vec::new();
        // 2 samples; sample 0 claims 2 events (20 B) but the trailing
        // bytes on disk are exactly its events + sample 1's prefix — so
        // honoring the claim would eat sample 1's prefix.
        for v in [MAGIC, VERSION, 8, 8, 2, 0, 2] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 20]); // sample 0's claimed events
        bytes.truncate(bytes.len() - 8); // ...but sample 1's prefix is missing
        std::fs::write(&path, &bytes).unwrap();
        let err = read_dataset(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `write_header` + `append_sample` compose into the exact layout
    /// `write_dataset` produces (the tail-file producer path).
    #[test]
    fn appended_samples_roundtrip() {
        let dir = std::env::temp_dir().join(format!("esda_io_app_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("appended.esda");
        let samples = vec![
            Sample { label: 1, events: vec![Event { t_us: 5, x: 2, y: 3, polarity: true }] },
            Sample { label: 2, events: vec![] },
        ];
        let mut f = std::fs::File::create(&path).unwrap();
        write_header(&mut f, 16, 12, samples.len()).unwrap();
        for s in &samples {
            append_sample(&mut f, s).unwrap();
        }
        drop(f);
        let (w, h, back) = read_dataset(&path).unwrap();
        assert_eq!((w, h), (16, 12));
        assert_eq!(back, samples);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn generate_files_balanced_labels() {
        let dir = std::env::temp_dir().join(format!("esda_io_gen_{}", std::process::id()));
        let p = DatasetProfile::n_mnist();
        let (train, test) = generate_dataset_files(&p, &dir, 2, 1, 7).unwrap();
        let (_, _, ts) = read_dataset(&train).unwrap();
        let (_, _, vs) = read_dataset(&test).unwrap();
        assert_eq!(ts.len(), p.n_classes * 2);
        assert_eq!(vs.len(), p.n_classes);
        for c in 0..p.n_classes as u32 {
            assert_eq!(ts.iter().filter(|s| s.label == c).count(), 2);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
