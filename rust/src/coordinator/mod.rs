//! L3 serving coordinator: the sharded event-vision serving runtime that
//! composes the substrates into a deployable system —
//!
//! ```text
//!                                      ┌ accel worker 0 ┐
//! event source → representation → ingress queue    …     → classifications
//!   (camera/        builder       (admission ─ accel worker N ─ + metrics
//!    synthetic)    (histogram2)    control)
//! ```
//!
//! Stages run on std threads connected by bounded queues (backpressure),
//! since the offline build vendors no async runtime. The event source is
//! any [`ingest::EventSource`] — the synthetic camera, a paced dataset
//! replay, a tailed capture file, or a UDP/TCP socket speaking the
//! [`net`] event-packet format — stamping real arrival times that
//! latency (and any `--slo-ms` deadline) is measured from. The
//! accelerator stage
//! is a pool of replicas — homogeneous (N workers sharing one [`Backend`]
//! trait object) or heterogeneous (a [`ReplicaPool`] of per-replica
//! instances across classes, with a cost-aware router picking a class per
//! request). The ingress queue applies admission control (block vs
//! drop-oldest), deadlines are enforced at the ingress, the router, and
//! the worker pop (see [`serve`]), and the merged [`metrics::Metrics`]
//! report per-worker and
//! per-class utilization, p50/p95/p99 latency percentiles, and SLO
//! attainment.
//!
//! [`run_pipeline`] is the single-accelerator batch-1 facade (the paper's
//! deployment); [`run_server`] is the replicated homogeneous runtime;
//! [`run_pool`] is the heterogeneous cost-aware runtime.
pub mod backend;
pub mod ingest;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod queue;
pub mod serve;

/// The coordinator-wide lock order. Every `Mutex`/`Condvar` in this module
/// tree declares one of these ranks via a `lock-rank(N): <name>` lint
/// directive; the static `lock-order` lint proves all nested acquisitions
/// are strictly rank-increasing (a partial-order proof of deadlock
/// freedom), and [`crate::util::lockcheck`] asserts the same invariant
/// dynamically in debug builds. Gaps between values are deliberate room
/// for future locks.
pub mod lock_ranks {
    /// `serve`'s run-wide first-error slot. Rank 0x0a: a worker that is
    /// failing must be able to record the error no matter what else it
    /// holds — so nothing may be held when it is taken, and it is ranked
    /// below every other lock.
    pub const FIRST_ERROR: u32 = 10;
    /// Admission-queue interior state ([`crate::coordinator::queue`]).
    /// Shared by the ingress, class, and side queues; queue operations
    /// never nest, so one rank covers every instance.
    pub const QUEUE_STATE: u32 = 20;
    /// Sticky router stream table (stream id -> worker).
    pub const STICKY_TABLE: u32 = 30;
    /// Sticky router side-queue directory, probed after the table.
    pub const STICKY_SIDES: u32 = 31;
    /// Per-class replica slot list; the scaler holds it while appending a
    /// scale-up event, so it ranks below [`SCALING_EVENTS`].
    pub const CLASS_SLOTS: u32 = 40;
    /// The run's scaling-event log.
    pub const SCALING_EVENTS: u32 = 41;
    /// Collected worker outputs, pushed at thread exit.
    pub const WORKER_OUTPUTS: u32 = 45;
    /// Autoscaler shutdown flag + condvar.
    pub const SCALER_STOP: u32 = 50;
    /// Shadow-capture writer shared by the workers.
    pub const SHADOW_CAPTURE: u32 = 60;
    /// `Swappable` backend's current-inner slot.
    pub const SWAP_INNER: u32 = 70;
    /// Functional backend's per-replica `ExecCtx` arena pool.
    pub const BACKEND_CTXS: u32 = 75;
    /// Shared delta-cache store (keyed by stream id).
    pub const DELTA_STORE: u32 = 76;
    /// Dense (PJRT) backend's engine handle.
    pub const DENSE_ENGINE: u32 = 77;
    /// Cost-model EWMA state; leaf rank — nothing is acquired under it.
    pub const COST_STATE: u32 = 80;
}

pub use backend::{
    Backend, BackendError, Classification, DeltaStatus, DeltaStore, Dense, Functional,
    PoolClass, ReplicaPool, ReplicaSpec, Shared, Simulator, Swappable, DEFAULT_MODEL,
};
pub use ingest::{
    EventSource, IngestError, MixSource, ReplaySource, SourcedRequest, SyntheticSource,
    TailSource, UnsortedPolicy, DEFAULT_TENANT,
};
pub use metrics::{
    ClassStats, CostModel, CostProfile, CostSnapshot, DeltaMetrics, Metrics, ModelStats,
    PercentileReport, RequestTiming, ScalingEvent, SlidingWindow, TenantStats, WorkerStats,
};
pub use net::{decode_packet, encode_packet, NetConfig, NetSource, Packet};
pub use pipeline::{run_pipeline, PipelineConfig, PipelineResult};
pub use queue::{AdmissionQueue, DropPolicy, TryPushError};
pub use serve::{
    run_pool, run_pool_source, run_server, run_server_source, synthetic_source, AutoscaleConfig,
    PipelineError, Prediction, ServerConfig, ServerResult, ShadowCaptureConfig, ShadowConfig,
    TenantConfig,
};

/// Shared unit-test fixtures (integration tests under `rust/tests/` keep
/// their own copies — crate-private test code is invisible to them).
#[cfg(test)]
pub(crate) mod testutil {
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::quant::{quantize_network, QuantizedNet};
    use crate::model::weights::FloatWeights;
    use crate::model::NetworkSpec;
    use crate::sparse::SparseMap;
    use crate::util::Rng;

    /// A tiny calibrated int8 network for `profile`.
    pub fn qnet_for(profile: &DatasetProfile) -> QuantizedNet {
        let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
        let w = FloatWeights::random(&spec, 3);
        let mut rng = Rng::new(9);
        let calib: Vec<SparseMap<f32>> = (0..2)
            .map(|i| {
                let es = profile.sample(i % profile.n_classes, &mut rng);
                histogram2_norm(&es, profile.w, profile.h, 8.0)
            })
            .collect();
        quantize_network(&spec, &w, &calib)
    }
}
