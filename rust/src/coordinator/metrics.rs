//! Latency/throughput metrics for the serving runtime: per-request
//! timings, admission-control accounting (drops, in-flight), per-worker
//! and per-class utilization, p50/p95/p99 percentile summaries, the
//! [`CostModel`] the heterogeneous router predicts service times with
//! (plus its persisted [`CostProfile`] form), the [`SlidingWindow`]
//! counters the autoscaler samples, and the [`ScalingEvent`] log it
//! leaves behind.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Per-request timing record.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    /// End-to-end latency (source arrival → classified), seconds. The
    /// arrival is the instant the request was born at its
    /// [`EventSource`](super::ingest::EventSource) — for a replayed or
    /// tailed stream that is when the recording window completed, so
    /// queue backlog shows up here exactly as it would in deployment.
    pub e2e_s: f64,
    /// Accelerator-stage service time, seconds.
    pub service_s: f64,
    /// Simulated hardware cycles (simulator backend only).
    pub sim_cycles: Option<u64>,
}

/// Percentile summary of a latency sample set. Percentiles interpolate
/// between order statistics, so for any nonempty sample
/// `p50 ≤ p95 ≤ p99 ≤ max` and the report is invariant under permutation
/// of the samples (both propcheck-verified below).
#[derive(Debug, Clone, Copy)]
pub struct PercentileReport {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Default for PercentileReport {
    fn default() -> Self {
        PercentileReport {
            n: 0,
            mean: f64::NAN,
            p50: f64::NAN,
            p95: f64::NAN,
            p99: f64::NAN,
            max: f64::NAN,
        }
    }
}

impl PercentileReport {
    /// Summarize a sample set (empty ⇒ all-NaN report). Built on
    /// [`Summary`] so there is exactly one percentile implementation in
    /// the crate — the propcheck properties below exercise it too.
    pub fn from_samples(xs: &[f64]) -> PercentileReport {
        let s = Summary::from(xs);
        if s.n() == 0 {
            return PercentileReport::default();
        }
        PercentileReport {
            n: s.n(),
            mean: s.mean(),
            p50: s.percentile(50.0),
            p95: s.percentile(95.0),
            p99: s.percentile(99.0),
            max: s.max(),
        }
    }
}

/// Per-class service-time predictor for the heterogeneous router: an EWMA
/// of observed per-request service seconds, bucketed by input sparsity
/// (log2 of the map's nonzero count), plus a class-wide EWMA fallback for
/// buckets with no observation yet. "Seeded from first requests": until a
/// class has served anything, [`CostModel::predict`] returns `None` and
/// the router probes it instead of trusting a made-up number.
#[derive(Debug, Default)]
pub struct CostModel {
    /// Leaf lock: `predict`/`observe` touch nothing else while holding
    /// it, so every other lock may already be held when it is taken.
    // lint: lock-rank(80): cost-state
    cost_state: Mutex<CostState>,
}

#[derive(Debug, Default)]
struct CostState {
    /// Class-wide EWMA over every observation (bucket fallback).
    global: Option<f64>,
    /// Per-bucket EWMAs, indexed by [`CostModel::bucket_of`].
    buckets: Vec<Option<f64>>,
}

impl CostModel {
    /// EWMA smoothing factor: heavy enough that a one-off hiccup doesn't
    /// repaint the class, light enough to track real drift within a run.
    pub const ALPHA: f64 = 0.25;

    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Event-count bucket: log2 of the input's nonzero count (empty maps
    /// share bucket 1 with single-event maps). Sparse service time scales
    /// with nnz, so log buckets give the predictor resolution where it
    /// matters without a bucket per exact count.
    pub fn bucket_of(nnz: usize) -> usize {
        (usize::BITS - nnz.max(1).leading_zeros()) as usize
    }

    /// Predicted per-request service seconds for `bucket`: the bucket EWMA
    /// when seeded, else the class-wide EWMA, else `None` (class never
    /// observed — the router must probe, not trust).
    pub fn predict(&self, bucket: usize) -> Option<f64> {
        let st = self.cost_state.lock().unwrap();
        st.buckets.get(bucket).copied().flatten().or(st.global)
    }

    /// Fold one observed per-request service time into the model.
    pub fn observe(&self, bucket: usize, service_s: f64) {
        if !service_s.is_finite() || service_s < 0.0 {
            return;
        }
        let mut guard = self.cost_state.lock().unwrap();
        let st = &mut *guard;
        if st.buckets.len() <= bucket {
            st.buckets.resize(bucket + 1, None);
        }
        for slot in [&mut st.buckets[bucket], &mut st.global] {
            *slot = Some(match *slot {
                Some(v) => v + Self::ALPHA * (service_s - v),
                None => service_s,
            });
        }
    }

    /// Snapshot the EWMA state for persistence ([`CostProfile`]).
    pub fn snapshot(&self) -> CostSnapshot {
        let st = self.cost_state.lock().unwrap();
        CostSnapshot { global: st.global, buckets: st.buckets.clone() }
    }

    /// Seed unobserved state from a persisted snapshot. Live observations
    /// always win: a slot that has already seen real traffic keeps its
    /// estimate, so stale profiles can only fill gaps, never repaint
    /// reality. Non-finite or negative persisted values are ignored (a
    /// hand-edited profile must not poison the router).
    pub fn seed(&self, snap: &CostSnapshot) {
        let ok = |v: Option<f64>| v.filter(|x| x.is_finite() && *x >= 0.0);
        let mut guard = self.cost_state.lock().unwrap();
        let st = &mut *guard;
        if st.global.is_none() {
            st.global = ok(snap.global);
        }
        if st.buckets.len() < snap.buckets.len() {
            st.buckets.resize(snap.buckets.len(), None);
        }
        for (slot, &persisted) in st.buckets.iter_mut().zip(&snap.buckets) {
            if slot.is_none() {
                *slot = ok(persisted);
            }
        }
    }
}

/// A [`CostModel`]'s persisted state: the class-wide EWMA plus the
/// per-bucket EWMAs (`None` = never observed), exactly mirroring
/// `CostState`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostSnapshot {
    pub global: Option<f64>,
    pub buckets: Vec<Option<f64>>,
}

impl CostSnapshot {
    /// Age at which persisted per-bucket costs expire (1 day): bucket
    /// estimates are fine-grained enough to drift with load mix, thermal
    /// state, and co-tenancy, so yesterday's buckets are probe-worthy
    /// again.
    pub const BUCKET_TTL_SECS: f64 = 86_400.0;
    /// Age at which even the class-wide mean expires (7 days): past a
    /// week the hardware/build may have changed outright.
    pub const GLOBAL_TTL_SECS: f64 = 604_800.0;

    /// True when nothing was ever observed (seeding from it is a no-op).
    pub fn is_empty(&self) -> bool {
        self.global.is_none() && self.buckets.iter().all(|b| b.is_none())
    }

    /// Tiered staleness decay, pure in the snapshot's age: per-bucket
    /// estimates survive [`CostSnapshot::BUCKET_TTL_SECS`], the class-wide
    /// mean survives [`CostSnapshot::GLOBAL_TTL_SECS`]. An unknown age
    /// (`f64::INFINITY` — e.g. a profile with no save stamp) decays
    /// everything: seeding from state of unknowable vintage is worse than
    /// probing.
    pub fn decayed(&self, age_secs: f64) -> CostSnapshot {
        let mut out = self.clone();
        if !(age_secs < Self::BUCKET_TTL_SECS) {
            out.buckets.iter_mut().for_each(|b| *b = None);
        }
        if !(age_secs < Self::GLOBAL_TTL_SECS) {
            out.global = None;
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("global", Json::opt_num(self.global)),
            ("buckets", Json::Arr(self.buckets.iter().map(|&b| Json::opt_num(b)).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CostSnapshot, String> {
        let num = |v: &Json| match v {
            Json::Null => Ok(None),
            Json::Num(n) => Ok(Some(*n)),
            other => Err(format!("expected number or null, got {other}")),
        };
        let global = num(j.req("global")?)?;
        let buckets = j
            .req("buckets")?
            .as_arr()
            .ok_or("'buckets' must be an array")?
            .iter()
            .map(num)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(CostSnapshot { global, buckets })
    }
}

/// On-disk cost profile: one [`CostSnapshot`] per replica class, written
/// at the end of a serving run (`serve --cost-profile path` rewrites it
/// at shutdown) and seeded into the next run's routers at startup — so a
/// freshly started pool, or a freshly scaled-up replica's class, predicts
/// from day-one reality instead of burning probe requests, and the SLO
/// shed can act before the first observation lands.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostProfile {
    pub classes: BTreeMap<String, CostSnapshot>,
    /// Unix seconds when [`CostProfile::save`] wrote the profile (`None`
    /// for in-memory profiles and pre-versioning files). Drives the
    /// staleness decay applied at seeding ([`CostProfile::age_secs`] +
    /// [`CostSnapshot::decayed`]).
    pub saved_unix: Option<f64>,
}

impl CostProfile {
    /// Profile format version (bump on incompatible layout changes).
    /// 1.1 added the `saved_unix` stamp.
    pub const VERSION: f64 = 1.1;

    pub fn is_empty(&self) -> bool {
        self.classes.values().all(|s| s.is_empty())
    }

    /// Seconds since the profile was saved: `f64::INFINITY` when it never
    /// was (or carries a garbage stamp), so unstamped state decays fully;
    /// a stamp from the future (clock skew) reads as fresh, not negative.
    pub fn age_secs(&self) -> f64 {
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        match self.saved_unix {
            Some(t) if t.is_finite() => (now - t).max(0.0),
            _ => f64::INFINITY,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(Self::VERSION)),
            ("saved_unix", Json::opt_num(self.saved_unix)),
            (
                "classes",
                Json::Obj(
                    self.classes.iter().map(|(k, v)| (k.clone(), v.to_json())).collect(),
                ),
            ),
        ])
    }

    /// Parse a profile document. A structurally broken document is an
    /// error; a *version mismatch* is not — the profile is advisory
    /// state, and an old file must never stop a serving run. Mismatches
    /// yield an empty profile plus a warning for the caller to surface,
    /// so nothing stale seeds the routers.
    pub fn from_json(j: &Json) -> Result<(CostProfile, Option<String>), String> {
        let version = j.req("version")?.as_f64().ok_or("'version' must be a number")?;
        if version != Self::VERSION {
            let warn = format!(
                "cost-profile version {version} != supported {} — ignoring persisted costs",
                Self::VERSION
            );
            return Ok((CostProfile::default(), Some(warn)));
        }
        let saved_unix = match j.get("saved_unix") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or("'saved_unix' must be a number or null")?),
        };
        let classes = j
            .req("classes")?
            .as_obj()
            .ok_or("'classes' must be an object")?
            .iter()
            .map(|(k, v)| Ok((k.clone(), CostSnapshot::from_json(v)?)))
            .collect::<Result<BTreeMap<_, _>, String>>()?;
        Ok((CostProfile { classes, saved_unix }, None))
    }

    /// Load a profile from disk (parse errors name the file; a version
    /// mismatch is a warning, not an error — see
    /// [`CostProfile::from_json`]).
    pub fn load(path: &Path) -> Result<(CostProfile, Option<String>), String> {
        let raw = std::fs::read_to_string(path)
            .map_err(|e| format!("cost profile {}: {e}", path.display()))?;
        let j = crate::util::json::parse(&raw)
            .map_err(|e| format!("cost profile {}: {e}", path.display()))?;
        CostProfile::from_json(&j).map_err(|e| format!("cost profile {}: {e}", path.display()))
    }

    /// Write the profile to disk (pretty-printing is not worth a
    /// dependency; the document is one line of JSON). The write is
    /// **atomic** — a sibling temp file renamed over the target — so a
    /// run killed mid-rewrite leaves the previous profile intact instead
    /// of a truncated file that would make every later
    /// `serve --cost-profile` fail at load.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        let ctx = |e: std::io::Error| format!("cost profile {}: {e}", path.display());
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(ctx)?;
            }
        }
        let file_name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| format!("cost profile {}: not a file path", path.display()))?;
        let tmp = path.with_file_name(format!("{file_name}.tmp"));
        // Stamp the write time so the next run can age what it seeds.
        let mut stamped = self.clone();
        stamped.saved_unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .ok()
            .map(|d| d.as_secs_f64());
        std::fs::write(&tmp, stamped.to_json().to_string()).map_err(ctx)?;
        std::fs::rename(&tmp, path).map_err(ctx)
    }
}

/// Windowed view over a monotonically non-decreasing counter: the caller
/// records `(now, total)` snapshots at its own cadence and reads how much
/// the counter grew across (roughly) the window. Old snapshots are
/// evicted, but the newest snapshot at-or-beyond the window edge is kept
/// so [`SlidingWindow::delta`] spans the full window instead of
/// collapsing to the last tick. The autoscaler keeps one per class for
/// deadline drops and accelerator-busy time.
#[derive(Debug)]
pub struct SlidingWindow {
    window: Duration,
    samples: VecDeque<(Instant, u64)>,
}

impl SlidingWindow {
    pub fn new(window: Duration) -> SlidingWindow {
        SlidingWindow { window, samples: VecDeque::new() }
    }

    /// Record a counter snapshot. `total` is cumulative; a regressing
    /// total (which a well-formed counter never produces) is clamped by
    /// the saturating read side rather than rejected here.
    pub fn record(&mut self, now: Instant, total: u64) {
        self.samples.push_back((now, total));
        // Evict from the front, but always leave one sample at-or-before
        // the window edge (and never fewer than two samples, so a delta
        // exists at all).
        while self.samples.len() > 2 {
            let second = self.samples[1].0;
            if now.duration_since(second) >= self.window {
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }

    /// Counter growth across the retained window (0 until two snapshots
    /// exist).
    pub fn delta(&self) -> u64 {
        match (self.samples.front(), self.samples.back()) {
            (Some(&(_, a)), Some(&(_, b))) => b.saturating_sub(a),
            _ => 0,
        }
    }

    /// Wall-clock span the retained snapshots cover, in seconds.
    pub fn span_secs(&self) -> f64 {
        match (self.samples.front(), self.samples.back()) {
            (Some(&(a, _)), Some(&(b, _))) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Growth rate (delta per second) over the retained span; 0.0 for a
    /// degenerate (empty or zero-length) window — never NaN.
    pub fn rate(&self) -> f64 {
        let span = self.span_secs();
        if span <= 0.0 {
            0.0
        } else {
            self.delta() as f64 / span
        }
    }
}

/// One autoscaler decision, recorded for the report's scaling log.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingEvent {
    /// Seconds since the run started.
    pub at_s: f64,
    /// Replica class the decision applied to.
    pub class: String,
    /// Active replicas before the step.
    pub from: usize,
    /// Active replicas after the step.
    pub to: usize,
    /// Human-readable trigger (deadline-drop rate, backlog, idleness, or
    /// a failed replica factory).
    pub reason: String,
}

/// Per-class accounting for the heterogeneous replica pool: who served
/// what, at what batch shape, and how well the routing cost model
/// predicted reality.
#[derive(Debug, Clone)]
pub struct ClassStats {
    /// Replica-class display name (e.g. `func`, `sim`, `dense`).
    pub class: String,
    /// Worker replicas active at the end of the run (the autoscaler moves
    /// this within `[replicas_min, replicas_max]`; without autoscaling it
    /// equals the configured count).
    pub replicas: usize,
    /// Lower replica bound (the count the class started with).
    pub replicas_min: usize,
    /// Upper replica bound the autoscaler may grow to (== `replicas_min`
    /// when the class is not scalable).
    pub replicas_max: usize,
    /// Highest simultaneously-active replica count seen during the run.
    pub replicas_peak: usize,
    /// Integrated active-replica capacity over the run, in replica-
    /// seconds (`replicas × wall` for a fixed class; the integral of the
    /// active count over time when the autoscaler moved it). This is the
    /// truthful utilization denominator — dividing by the *final* count
    /// would over- or under-report whenever a run ends at a different
    /// size than it mostly ran at. 0.0 on hand-built stats ⇒
    /// [`ClassStats::utilization`] falls back to `wall × replicas`.
    pub replica_s: f64,
    /// Requests this class served.
    pub served: usize,
    /// Accelerator visits (micro-batches) this class made.
    pub batches: usize,
    /// Total accelerator-busy seconds across the class's replicas.
    pub busy_s: f64,
    /// Batch-size percentiles across this class's visits.
    pub batch: PercentileReport,
    /// Service-latency percentiles for requests this class served.
    pub service: PercentileReport,
    /// Mean relative routing-cost error `|predicted − actual| / actual`
    /// over requests routed with a seeded predictor (NaN when none were).
    pub cost_err: f64,
    /// Requests routed to this class before its cost model had any
    /// observation (the probe traffic that seeds the EWMA).
    pub unseeded: usize,
    /// Requests bound for this class that were shed on deadline grounds:
    /// the router predicted this (best) class could not complete them in
    /// time, or they expired in the class's queue before a worker reached
    /// them.
    pub deadline_drops: usize,
}

impl ClassStats {
    /// Mean fraction of the class's active capacity spent serving:
    /// `busy_s` over the integrated replica-seconds (`replica_s`) when
    /// the runtime filled them, else over `wall_s × replicas` (the
    /// fixed-class equivalent, kept for hand-built stats). Using the
    /// integral matters for autoscaled classes: a run that mostly ran at
    /// 4 replicas but ended scaled back to 1 must not divide four
    /// replicas' busy time by one replica's wall clock. A degenerate
    /// window (zero/negative/non-finite denominator) reports 0.0 — not
    /// NaN/inf, which `util::json` would serialize as `null` deep inside
    /// a report.
    pub fn utilization(&self, wall_s: f64) -> f64 {
        if self.replica_s.is_finite() && self.replica_s > 0.0 {
            return self.busy_s / self.replica_s;
        }
        if !(wall_s > 0.0 && wall_s.is_finite()) || self.replicas == 0 {
            return 0.0;
        }
        self.busy_s / (wall_s * self.replicas as f64)
    }
}

/// Per-tenant accounting for the multi-tenant front door: every request a
/// tenant's stream offered ends up in exactly one of these buckets, so
/// `served + dropped + deadline drops + ingest rejects` reconstructs the
/// tenant's offered load (see [`TenantStats::offered`] — the conservation
/// law the serving propcheck tests assert per tenant).
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    /// Tenant display name (`default` for single-tenant runs).
    pub tenant: String,
    /// Fair-share weight the admission quota was derived from.
    pub weight: usize,
    /// Ingress-queue slots this tenant may occupy at once (its weighted
    /// share of the queue depth; the full depth for single-tenant runs).
    pub quota: usize,
    /// Requests of this tenant that were classified.
    pub served: usize,
    /// Requests shed by admission control: drop-oldest evictions of this
    /// tenant's queued requests plus over-quota arrivals.
    pub dropped: usize,
    /// Deadline-carrying requests this tenant offered (its SLO-attainment
    /// denominator).
    pub deadline_offered: usize,
    /// This tenant's requests already expired at the ingress.
    pub deadline_ingress: usize,
    /// This tenant's requests shed at the router or expired at a worker
    /// pop.
    pub deadline_router: usize,
    /// Served within the deadline.
    pub deadline_met: usize,
    /// Served, but late (counts as served and against the SLO).
    pub deadline_missed: usize,
    /// Recoverable per-sample validation rejects attributed to this tenant
    /// at the source boundary (the stream continued past them).
    pub ingest_rejects: usize,
}

impl TenantStats {
    /// Total deadline-based sheds for this tenant.
    pub fn deadline_drops(&self) -> usize {
        self.deadline_ingress + self.deadline_router
    }

    /// Requests this tenant's stream offered: everything lands in exactly
    /// one of served / dropped / deadline-shed / ingest-rejected.
    pub fn offered(&self) -> usize {
        self.served + self.dropped + self.deadline_drops() + self.ingest_rejects
    }

    /// Per-tenant SLO attainment, with the same strict denominator as
    /// [`Metrics::slo_attainment`]: every deadline-carrying request this
    /// tenant offered, not just the served ones. `None` when the tenant
    /// carried no deadline.
    pub fn slo_attainment(&self) -> Option<f64> {
        if self.deadline_offered == 0 {
            return None;
        }
        Some(self.deadline_met as f64 / self.deadline_offered as f64)
    }
}

/// Per-model accounting for fleet serving: one entry per distinct model
/// tag in the replica-class table, keyed by the model id requests carry.
/// Each row obeys the same conservation identity as the tenant books —
/// `served + dropped + deadline drops` reconstructs the model's offered
/// load (see [`ModelStats::offered`]) — and additionally carries the
/// shadow-conformance books when the model had a `--shadow` candidate.
/// A single-model run has exactly one row restating the global books.
#[derive(Debug, Clone, Default)]
pub struct ModelStats {
    /// Model display name (the class tag; `default` for untagged runs).
    pub model: String,
    /// Replica classes serving this model.
    pub classes: usize,
    /// Requests of this model that were classified (by the *primary*;
    /// shadow mirrors are observations, not service).
    pub served: usize,
    /// Served requests whose prediction matched the ground-truth label.
    pub correct: usize,
    /// Requests shed by admission control (evictions + over-quota).
    pub dropped: usize,
    /// Deadline-carrying requests of this model.
    pub deadline_offered: usize,
    /// Already expired at the ingress.
    pub deadline_ingress: usize,
    /// Shed at the router or expired at a worker pop.
    pub deadline_router: usize,
    /// Served requests mirrored to the shadow candidate (0 without one).
    pub shadow_mirrored: usize,
    /// Mirrored requests where the candidate's prediction differed from
    /// the primary's — the shadow-conformance failure count.
    pub shadow_disagreements: usize,
    /// Disagreements that could not be captured to the `.esda` sidecar
    /// (cap reached, or an IO error) — counted so the capture file's
    /// coverage is never silently partial.
    pub shadow_capture_drops: usize,
}

impl ModelStats {
    /// Total deadline-based sheds for this model.
    pub fn deadline_drops(&self) -> usize {
        self.deadline_ingress + self.deadline_router
    }

    /// Requests offered to this model: everything lands in exactly one of
    /// served / dropped / deadline-shed.
    pub fn offered(&self) -> usize {
        self.served + self.dropped + self.deadline_drops()
    }

    /// Accuracy over this model's served requests (`None` when none).
    pub fn accuracy(&self) -> Option<f64> {
        if self.served == 0 {
            return None;
        }
        Some(self.correct as f64 / self.served as f64)
    }

    /// Shadow disagreement rate over mirrored requests (`None` when the
    /// model had no shadow traffic).
    pub fn disagreement_rate(&self) -> Option<f64> {
        if self.shadow_mirrored == 0 {
            return None;
        }
        Some(self.shadow_disagreements as f64 / self.shadow_mirrored as f64)
    }
}

/// Per-worker accounting for the replicated accelerator pool.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    /// Worker replica index.
    pub worker: usize,
    /// Replica class this worker belongs to. The serving runtime always
    /// fills it (the homogeneous path uses the backend's `name()`); it is
    /// empty only on hand-built `Default` values, which the report renders
    /// as a dash.
    pub class: String,
    /// Requests this replica served.
    pub served: usize,
    /// Accelerator visits (micro-batches) this replica made;
    /// `served / batches` is its mean batch size.
    pub batches: usize,
    /// Total accelerator-busy seconds.
    pub busy_s: f64,
    /// Service-latency percentiles for this replica.
    pub service: PercentileReport,
    /// End-to-end latency percentiles for requests this replica served.
    pub e2e: PercentileReport,
    /// Batch-size percentiles across this replica's accelerator visits.
    pub batch: PercentileReport,
}

impl WorkerStats {
    /// Fraction of the wall-clock interval this replica spent serving.
    /// 0.0 for a degenerate window (see [`ClassStats::utilization`]).
    pub fn utilization(&self, wall_s: f64) -> f64 {
        if !(wall_s > 0.0 && wall_s.is_finite()) {
            return 0.0;
        }
        self.busy_s / wall_s
    }
}

/// Books for incremental (delta) execution across overlapping windows and
/// the sticky routing that keeps a stream's cache warm. A *delta attempt*
/// is a request that reached a delta-capable backend with a stream
/// identity; it lands in exactly one of hit / cold / geometry /
/// over-threshold. `not_applicable` counts everything else (no stream, or
/// a backend without delta support). The sticky counters book the router's
/// affinity decisions, which are independent of the execution outcome —
/// a non-sticky hop can still delta-hit off the shared cache store.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeltaMetrics {
    /// Requests served incrementally (diff + partial recompute).
    pub hits: usize,
    /// Full recomputes: the stream had no usable cached window.
    pub full_cold: usize,
    /// Full recomputes: the cached window's geometry/plan changed.
    pub full_geometry: usize,
    /// Full recomputes: the dirty fraction exceeded the threshold.
    pub full_over_threshold: usize,
    /// Requests outside the delta machinery entirely.
    pub not_applicable: usize,
    /// Σ dirty-input-site fraction over hits.
    pub dirty_frac_sum: f64,
    /// Σ recomputed-site fraction over hits.
    pub recomputed_frac_sum: f64,
    /// Sticky routing: requests delivered to their stream's affine worker.
    pub sticky_hits: usize,
    /// Sticky routing: stream had no affinity yet (first sight).
    pub sticky_cold: usize,
    /// Sticky routing: the affine worker was retired (entry dropped,
    /// request cost-routed).
    pub sticky_retired: usize,
    /// Sticky routing: the affine worker's queue was full (request
    /// cost-routed; affinity kept).
    pub sticky_capacity: usize,
}

impl DeltaMetrics {
    /// Requests that entered the delta machinery at all.
    pub fn attempts(&self) -> usize {
        self.hits + self.full_cold + self.full_geometry + self.full_over_threshold
    }

    /// Fraction of delta attempts served incrementally (NaN when none).
    pub fn hit_rate(&self) -> f64 {
        if self.attempts() == 0 {
            return f64::NAN;
        }
        self.hits as f64 / self.attempts() as f64
    }

    /// Mean dirty-input fraction across hits (NaN when none).
    pub fn mean_dirty_frac(&self) -> f64 {
        if self.hits == 0 {
            return f64::NAN;
        }
        self.dirty_frac_sum / self.hits as f64
    }

    /// Mean recomputed-site fraction across hits (NaN when none).
    pub fn mean_recomputed_frac(&self) -> f64 {
        if self.hits == 0 {
            return f64::NAN;
        }
        self.recomputed_frac_sum / self.hits as f64
    }

    /// Field-wise accumulate (per-worker books → run totals).
    pub fn merge(&mut self, o: &DeltaMetrics) {
        self.hits += o.hits;
        self.full_cold += o.full_cold;
        self.full_geometry += o.full_geometry;
        self.full_over_threshold += o.full_over_threshold;
        self.not_applicable += o.not_applicable;
        self.dirty_frac_sum += o.dirty_frac_sum;
        self.recomputed_frac_sum += o.recomputed_frac_sum;
        self.sticky_hits += o.sticky_hits;
        self.sticky_cold += o.sticky_cold;
        self.sticky_retired += o.sticky_retired;
        self.sticky_capacity += o.sticky_capacity;
    }
}

/// Aggregated serving metrics.
#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub timings: Vec<RequestTiming>,
    pub correct: usize,
    pub total: usize,
    /// Requests evicted by admission control (drop-oldest under saturation).
    /// (Requests stranded by an aborted run are not in any `Metrics` —
    /// they're reported via `PipelineError::in_flight` on the error path.)
    pub dropped: usize,
    /// Deadline-carrying requests that entered the system (the SLO
    /// attainment denominator; 0 when no `--slo-ms` was set).
    pub deadline_offered: usize,
    /// Requests already past their deadline at the ingress (dropped
    /// before admission — they never occupied a queue slot).
    pub deadline_ingress: usize,
    /// Requests shed at the scheduling point: the router's predictive
    /// shed (no class's predicted completion met the deadline) plus
    /// expiries at the worker pop — the routerless single-class path's
    /// scheduling point, and the post-route safety net in pools.
    pub deadline_router: usize,
    /// Served requests that completed within their deadline.
    pub deadline_met: usize,
    /// Served requests that completed *after* their deadline (they count
    /// as served, but against SLO attainment).
    pub deadline_missed: usize,
    /// Recoverable per-sample rejects at the source boundary (corrupt or
    /// out-of-geometry samples the stream skipped past). These requests
    /// never reached admission, so they are *not* part of
    /// [`Metrics::offered`] — they are the gap between what the source
    /// emitted and what the system was offered.
    pub ingest_rejects: usize,
    /// Per-tenant books, one entry per configured tenant (a single
    /// `default` entry when no tenants were configured).
    pub per_tenant: Vec<TenantStats>,
    /// Per-model fleet books, one entry per distinct model tag (a single
    /// `default` entry for untagged runs).
    pub per_model: Vec<ModelStats>,
    /// Per-replica stats, one entry per pool worker (the single-
    /// accelerator `run_pipeline` facade has exactly one).
    pub per_worker: Vec<WorkerStats>,
    /// Per-class stats, one entry per replica class of the heterogeneous
    /// pool (a single entry for the homogeneous `run_server` path).
    pub per_class: Vec<ClassStats>,
    /// Size of every micro-batch any worker pulled from the ingress queue
    /// (one entry per accelerator visit, across all workers).
    pub batch_sizes: Vec<usize>,
    /// Autoscaler decisions in the order they were taken (empty without
    /// autoscaling).
    pub scaling_events: Vec<ScalingEvent>,
    /// Final per-class cost-model snapshots — what `--cost-profile`
    /// rewrites at shutdown (empty snapshots for classes that never
    /// observed, e.g. the routerless single-class path).
    pub cost_profile: CostProfile,
    /// Incremental-execution and sticky-routing books (all zero when
    /// `--delta` was off).
    pub delta: DeltaMetrics,
    /// Wall-clock duration of the completed run in seconds (0 until the
    /// runtime finalizes it — see [`Metrics::wall_seconds`]).
    pub wall_s: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            timings: Vec::new(),
            correct: 0,
            total: 0,
            dropped: 0,
            deadline_offered: 0,
            deadline_ingress: 0,
            deadline_router: 0,
            deadline_met: 0,
            deadline_missed: 0,
            ingest_rejects: 0,
            per_tenant: Vec::new(),
            per_model: Vec::new(),
            per_worker: Vec::new(),
            per_class: Vec::new(),
            batch_sizes: Vec::new(),
            scaling_events: Vec::new(),
            cost_profile: CostProfile::default(),
            delta: DeltaMetrics::default(),
            wall_s: 0.0,
        }
    }
}

impl Metrics {
    pub fn record(&mut self, t: RequestTiming, correct: bool) {
        self.timings.push(t);
        self.total += 1;
        if correct {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.correct as f64 / self.total as f64
    }

    /// Requests offered to the system: served + queue-full drops +
    /// deadline drops (without an SLO the deadline terms are 0, so this
    /// stays served + dropped).
    pub fn offered(&self) -> usize {
        self.total + self.dropped + self.deadline_drops()
    }

    /// Fraction of offered requests shed by queue-full admission control
    /// (deadline sheds are reported separately — see
    /// [`Metrics::deadline_drops`]).
    pub fn drop_rate(&self) -> f64 {
        if self.offered() == 0 {
            return 0.0;
        }
        self.dropped as f64 / self.offered() as f64
    }

    /// Total deadline-based sheds, distinguished from queue-full drops:
    /// ingress expiries plus router/scheduling-point sheds.
    pub fn deadline_drops(&self) -> usize {
        self.deadline_ingress + self.deadline_router
    }

    /// SLO attainment: the fraction of deadline-carrying requests that
    /// were served within their deadline. Everything else — ingress
    /// expiry, router shed, queue-full drop, served-but-late — counts
    /// against it: the denominator is every request *offered* with a
    /// deadline, never just the served ones, so a run that sheds 90% of
    /// its traffic cannot report 100% attainment. (The served-only
    /// figure, useful for judging replica speed in isolation, is
    /// [`Metrics::slo_attainment_served`].) `None` when no request
    /// carried a deadline (no SLO configured).
    pub fn slo_attainment(&self) -> Option<f64> {
        if self.deadline_offered == 0 {
            return None;
        }
        Some(self.deadline_met as f64 / self.deadline_offered as f64)
    }

    /// Served-only SLO attainment: of the deadline-carrying requests that
    /// actually reached a backend, the fraction that completed in time.
    /// This deliberately ignores sheds and drops — it measures replica
    /// speed, not end-to-end service quality; headline SLO reporting must
    /// use [`Metrics::slo_attainment`]. `None` when no deadline-carrying
    /// request was served.
    pub fn slo_attainment_served(&self) -> Option<f64> {
        let served = self.deadline_met + self.deadline_missed;
        if served == 0 {
            return None;
        }
        Some(self.deadline_met as f64 / served as f64)
    }

    pub fn e2e_summary(&self) -> Summary {
        Summary::from(&self.timings.iter().map(|t| t.e2e_s).collect::<Vec<_>>())
    }

    pub fn service_summary(&self) -> Summary {
        Summary::from(&self.timings.iter().map(|t| t.service_s).collect::<Vec<_>>())
    }

    /// Aggregated end-to-end latency percentiles.
    pub fn e2e_percentiles(&self) -> PercentileReport {
        PercentileReport::from_samples(&self.timings.iter().map(|t| t.e2e_s).collect::<Vec<_>>())
    }

    /// Aggregated service-latency percentiles.
    pub fn service_percentiles(&self) -> PercentileReport {
        PercentileReport::from_samples(
            &self.timings.iter().map(|t| t.service_s).collect::<Vec<_>>(),
        )
    }

    /// Wall-clock duration of the run: the finalized duration recorded by
    /// the serving runtime, or time-since-start while still in flight —
    /// so utilization/throughput don't dilute when a result is rendered
    /// long after the run completed.
    pub fn wall_seconds(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.wall_s
        } else {
            self.started.elapsed().as_secs_f64()
        }
    }

    /// Wall-clock throughput (requests/s).
    pub fn throughput(&self) -> f64 {
        let dt = self.wall_seconds();
        if dt <= 0.0 {
            return f64::NAN;
        }
        self.total as f64 / dt
    }

    /// Batch-size distribution across all accelerator visits (empty ⇒
    /// all-NaN report, as with the latency percentiles).
    pub fn batch_percentiles(&self) -> PercentileReport {
        PercentileReport::from_samples(
            &self.batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
    }

    /// Mean requests per accelerator visit (NaN with no visits). 1.0 means
    /// micro-batching never coalesced anything.
    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return f64::NAN;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Mean simulated hardware latency in ms at `clock_hz`, when available.
    pub fn mean_sim_latency_ms(&self, clock_hz: f64) -> Option<f64> {
        let cycles: Vec<f64> = self
            .timings
            .iter()
            .filter_map(|t| t.sim_cycles.map(|c| c as f64))
            .collect();
        if cycles.is_empty() {
            return None;
        }
        Some(cycles.iter().sum::<f64>() / cycles.len() as f64 / clock_hz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn model_stats_books_balance() {
        let m = ModelStats {
            model: "alpha".into(),
            classes: 2,
            served: 10,
            correct: 7,
            dropped: 3,
            deadline_ingress: 2,
            deadline_router: 1,
            shadow_mirrored: 4,
            shadow_disagreements: 1,
            ..Default::default()
        };
        assert_eq!(m.deadline_drops(), 3);
        assert_eq!(m.offered(), 16, "served + dropped + deadline drops");
        assert_eq!(m.accuracy(), Some(0.7));
        assert_eq!(m.disagreement_rate(), Some(0.25));
        let empty = ModelStats::default();
        assert_eq!(empty.accuracy(), None, "no service ⇒ no accuracy claim");
        assert_eq!(empty.disagreement_rate(), None, "no mirror ⇒ no rate claim");
    }

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.010, service_s: 0.002, sim_cycles: Some(1000) }, true);
        m.record(RequestTiming { e2e_s: 0.020, service_s: 0.004, sim_cycles: Some(3000) }, false);
        assert_eq!(m.total, 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert!((m.e2e_summary().mean() - 0.015).abs() < 1e-9);
        let lat = m.mean_sim_latency_ms(1e6).unwrap();
        assert!((lat - 2.0).abs() < 1e-9); // 2000 cycles avg @1MHz = 2ms
    }

    #[test]
    fn drop_accounting() {
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.01, service_s: 0.01, sim_cycles: None }, true);
        m.dropped = 3;
        assert_eq!(m.offered(), 4);
        assert!((m.drop_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_report_known_values() {
        let p = PercentileReport::from_samples(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(p.n, 4);
        assert!((p.mean - 2.5).abs() < 1e-12);
        assert!((p.p50 - 2.5).abs() < 1e-12);
        assert!((p.max - 4.0).abs() < 1e-12);
        // Empty set is explicit about having no data.
        let e = PercentileReport::from_samples(&[]);
        assert_eq!(e.n, 0);
        assert!(e.p50.is_nan() && e.max.is_nan());
    }

    /// Property: percentiles are monotone in q and bounded by the extremes.
    #[test]
    fn percentile_ordering_property() {
        check("p50 ≤ p95 ≤ p99 ≤ max", 256, |g: &mut Gen| {
            let n = g.usize(1, 200);
            let xs: Vec<f64> = (0..n).map(|_| g.f64() * 10.0 - 5.0).collect();
            let p = PercentileReport::from_samples(&xs);
            assert!(p.p50 <= p.p95, "p50 {} > p95 {}", p.p50, p.p95);
            assert!(p.p95 <= p.p99, "p95 {} > p99 {}", p.p95, p.p99);
            assert!(p.p99 <= p.max, "p99 {} > max {}", p.p99, p.max);
            let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(p.p50 >= lo && p.max <= hi);
            assert!(p.mean >= lo - 1e-12 && p.mean <= hi + 1e-12);
        });
    }

    /// Property: the report depends only on the sample multiset, not order.
    #[test]
    fn percentile_permutation_invariance() {
        check("percentiles are permutation-invariant", 128, |g: &mut Gen| {
            let n = g.usize(1, 64);
            let mut xs: Vec<f64> = (0..n).map(|_| g.f64() * 100.0).collect();
            let p1 = PercentileReport::from_samples(&xs);
            // Fisher–Yates shuffle driven by the property's generator.
            for i in (1..xs.len()).rev() {
                let j = g.usize(0, i);
                xs.swap(i, j);
            }
            let p2 = PercentileReport::from_samples(&xs);
            // Same sorted array ⇒ bitwise-identical outputs.
            for (a, b) in [(p1.p50, p2.p50), (p1.p95, p2.p95), (p1.p99, p2.p99), (p1.max, p2.max)]
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        });
    }

    #[test]
    fn batch_distribution() {
        let mut m = Metrics::default();
        assert!(m.mean_batch().is_nan());
        assert_eq!(m.batch_percentiles().n, 0);
        m.batch_sizes.extend_from_slice(&[1, 4, 4, 7]);
        assert!((m.mean_batch() - 4.0).abs() < 1e-12);
        let p = m.batch_percentiles();
        assert_eq!(p.n, 4);
        assert!((p.max - 7.0).abs() < 1e-12);
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.max);
    }

    #[test]
    fn worker_utilization() {
        let w = WorkerStats { worker: 0, served: 10, busy_s: 0.5, ..Default::default() };
        assert!((w.utilization(1.0) - 0.5).abs() < 1e-12);
    }

    fn class_stats(replicas: usize, busy_s: f64) -> ClassStats {
        ClassStats {
            class: "func".into(),
            replicas,
            replicas_min: replicas,
            replicas_max: replicas,
            replicas_peak: replicas,
            replica_s: 0.0,
            served: 8,
            batches: 4,
            busy_s,
            batch: PercentileReport::default(),
            service: PercentileReport::default(),
            cost_err: f64::NAN,
            unseeded: 0,
            deadline_drops: 0,
        }
    }

    #[test]
    fn class_utilization_divides_by_replicas() {
        let c = class_stats(2, 1.0);
        assert!((c.utilization(1.0) - 0.5).abs() < 1e-12);
    }

    /// With integrated replica-seconds filled, utilization uses them
    /// instead of `wall × final count` — an autoscaled class that mostly
    /// ran at 4 replicas but ended at 1 must not report >100%.
    #[test]
    fn class_utilization_uses_integrated_replica_seconds() {
        let mut c = class_stats(1, 3.0); // ended scaled back down to 1
        // Ran 4 replicas for 0.9 s + 1 replica for 0.1 s of a 1 s run.
        c.replica_s = 4.0 * 0.9 + 1.0 * 0.1;
        let u = c.utilization(1.0);
        assert!((u - 3.0 / 3.7).abs() < 1e-12, "got {u}");
        assert!(u <= 1.0, "utilization must not exceed 100%: {u}");
        // Degenerate integral falls back to the fixed-class denominator.
        c.replica_s = 0.0;
        assert!((c.utilization(1.0) - 3.0).abs() < 1e-12);
    }

    /// Regression (degenerate-window utilization): a zero-duration run
    /// used to yield NaN/inf here, which `util::json` serializes as
    /// `null` deep inside the report — degenerate windows must read as
    /// 0.0 exactly.
    #[test]
    fn utilization_degenerate_window_is_zero() {
        let w = WorkerStats { worker: 0, served: 1, busy_s: 0.5, ..Default::default() };
        for wall in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert_eq!(w.utilization(wall), 0.0, "wall_s {wall}");
        }
        let c = class_stats(2, 1.0);
        for wall in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert_eq!(c.utilization(wall), 0.0, "wall_s {wall}");
        }
        let no_replicas = class_stats(0, 1.0);
        assert_eq!(no_replicas.utilization(1.0), 0.0);
        // The JSON a report would embed stays a real number.
        assert_eq!(Json::Num(w.utilization(0.0)).to_string(), "0");
    }

    /// Deadline books: attainment over every deadline-carrying request,
    /// deadline drops distinct from queue-full drops, and `None` when no
    /// SLO was configured.
    #[test]
    fn slo_attainment_accounting() {
        let mut m = Metrics::default();
        assert_eq!(m.slo_attainment(), None, "no SLO ⇒ no attainment figure");
        assert_eq!(m.deadline_drops(), 0);
        // 10 deadline-carrying requests offered: 6 met, 1 served late,
        // 1 expired at ingress, 1 shed at the router, 1 queue-dropped.
        m.deadline_offered = 10;
        m.deadline_met = 6;
        m.deadline_missed = 1;
        m.deadline_ingress = 1;
        m.deadline_router = 1;
        m.dropped = 1;
        m.total = 7; // 6 met + 1 late
        assert_eq!(m.deadline_drops(), 2);
        assert_eq!(m.offered(), 10, "served + queue drops + deadline drops");
        assert!((m.slo_attainment().unwrap() - 0.6).abs() < 1e-12);
        assert!((m.drop_rate() - 0.1).abs() < 1e-12, "queue drops only");
        // Served-only attainment ignores the sheds: 6 of 7 served in time.
        assert!((m.slo_attainment_served().unwrap() - 6.0 / 7.0).abs() < 1e-12);
    }

    /// Regression (shed-heavy attainment semantics): a run that sheds 90%
    /// of its deadline-carrying traffic at the router must not report
    /// 100% attainment — sheds are misses in the denominator. The
    /// served-only figure stays available as its own accessor.
    #[test]
    fn slo_attainment_counts_sheds_as_misses() {
        let mut m = Metrics::default();
        m.deadline_offered = 100;
        m.deadline_met = 10; // the 10 requests that reached a backend, all in time
        m.deadline_missed = 0;
        m.deadline_router = 90; // everything else shed at the router
        m.total = 10;
        assert_eq!(
            m.slo_attainment(),
            Some(0.1),
            "90% router-shed traffic must count against attainment"
        );
        assert_eq!(m.slo_attainment_served(), Some(1.0), "served-only view: all in time");
        // No served deadline-carrying requests at all: served-only is N/A,
        // strict attainment is 0.
        let mut m = Metrics::default();
        m.deadline_offered = 5;
        m.deadline_ingress = 5;
        assert_eq!(m.slo_attainment(), Some(0.0));
        assert_eq!(m.slo_attainment_served(), None);
    }

    /// Per-tenant books: the conservation identity behind
    /// [`TenantStats::offered`], strict-denominator attainment, and `None`
    /// attainment for a tenant that never carried a deadline.
    #[test]
    fn tenant_stats_books_balance() {
        let t = TenantStats {
            tenant: "cam0".into(),
            weight: 3,
            quota: 3,
            served: 10,
            dropped: 2,
            deadline_offered: 12,
            deadline_ingress: 1,
            deadline_router: 1,
            deadline_met: 9,
            deadline_missed: 1,
            ingest_rejects: 2,
        };
        assert_eq!(t.deadline_drops(), 2);
        assert_eq!(t.offered(), 10 + 2 + 2 + 2);
        assert!((t.slo_attainment().unwrap() - 0.75).abs() < 1e-12);
        let quiet = TenantStats { tenant: "cam1".into(), served: 4, ..Default::default() };
        assert_eq!(quiet.slo_attainment(), None, "no deadline ⇒ no attainment figure");
        assert_eq!(quiet.offered(), 4);
    }

    #[test]
    fn cost_model_buckets_by_log2_nnz() {
        assert_eq!(CostModel::bucket_of(0), 1);
        assert_eq!(CostModel::bucket_of(1), 1);
        assert_eq!(CostModel::bucket_of(2), 2);
        assert_eq!(CostModel::bucket_of(3), 2);
        assert_eq!(CostModel::bucket_of(1024), 11);
        assert!(CostModel::bucket_of(usize::MAX) as u32 <= usize::BITS);
    }

    /// Unseeded ⇒ `None`; a bucket observation seeds that bucket; other
    /// buckets fall back to the class-wide EWMA; observations move the
    /// estimate toward recent reality.
    #[test]
    fn cost_model_seeds_and_tracks() {
        let m = CostModel::new();
        assert_eq!(m.predict(3), None, "never-observed class must not invent a cost");
        m.observe(3, 0.010);
        assert!((m.predict(3).unwrap() - 0.010).abs() < 1e-12);
        // A different bucket falls back to the class-wide estimate.
        assert!((m.predict(7).unwrap() - 0.010).abs() < 1e-12);
        // EWMA moves toward a faster observation but doesn't jump to it.
        m.observe(3, 0.002);
        let p = m.predict(3).unwrap();
        assert!(p < 0.010 && p > 0.002, "EWMA out of range: {p}");
        // Garbage observations are ignored.
        m.observe(3, f64::NAN);
        m.observe(3, -1.0);
        assert!((m.predict(3).unwrap() - p).abs() < 1e-15);
    }

    /// Seeding fills gaps but never overrides live observations, and
    /// rejects non-finite/negative persisted values.
    #[test]
    fn cost_model_seed_fills_gaps_only() {
        let m = CostModel::new();
        m.observe(2, 0.004);
        let snap = CostSnapshot {
            global: Some(0.5),
            buckets: vec![None, Some(0.010), Some(0.999), Some(f64::NAN), Some(-1.0)],
        };
        m.seed(&snap);
        // Bucket 2 and the global EWMA were live: the profile must not
        // repaint them.
        assert!((m.predict(2).unwrap() - 0.004).abs() < 1e-12);
        // Bucket 1 was empty: seeded from the profile.
        assert!((m.predict(1).unwrap() - 0.010).abs() < 1e-12);
        // Poisoned slots (NaN, negative) are ignored — those buckets fall
        // back to the (live) global EWMA.
        assert!((m.predict(3).unwrap() - 0.004).abs() < 1e-12);
        assert!((m.predict(4).unwrap() - 0.004).abs() < 1e-12);
        // A fresh model adopts the persisted global too.
        let fresh = CostModel::new();
        fresh.seed(&snap);
        assert!((fresh.predict(7).unwrap() - 0.5).abs() < 1e-12);
    }

    /// Property: snapshot → JSON → parse → seed a fresh model ⇒ identical
    /// predictions for every bucket (the cost-profile round-trip the
    /// persistence path depends on).
    #[test]
    fn cost_profile_roundtrip_property() {
        check("cost profile json roundtrip preserves predictions", 64, |g: &mut Gen| {
            let m = CostModel::new();
            let n_obs = g.usize(0, 40);
            for _ in 0..n_obs {
                m.observe(g.usize(0, 12), g.f64() * 0.01);
            }
            let profile = CostProfile {
                classes: [("c".to_string(), m.snapshot())].into_iter().collect(),
                saved_unix: Some(1_700_000_000.0),
            };
            let doc = profile.to_json().to_string();
            let parsed = crate::util::json::parse(&doc)
                .unwrap_or_else(|e| panic!("invalid profile JSON: {e}\n{doc}"));
            let (back, warn) = CostProfile::from_json(&parsed).expect("well-formed profile");
            assert_eq!(warn, None, "doc: {doc}");
            assert_eq!(back, profile, "doc: {doc}");
            let fresh = CostModel::new();
            fresh.seed(&back.classes["c"]);
            for bucket in 0..16 {
                assert_eq!(
                    fresh.predict(bucket),
                    m.predict(bucket),
                    "bucket {bucket} diverged after roundtrip"
                );
            }
        });
    }

    #[test]
    fn cost_profile_save_load_roundtrip_and_rejects_garbage() {
        let m = CostModel::new();
        m.observe(3, 0.002);
        m.observe(5, 0.008);
        let profile = CostProfile {
            classes: [("func".to_string(), m.snapshot())].into_iter().collect(),
            saved_unix: None,
        };
        let dir = std::env::temp_dir().join(format!("esda_costprof_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profile.json");
        profile.save(&path).unwrap();
        let (back, warn) = CostProfile::load(&path).unwrap();
        assert_eq!(warn, None);
        assert_eq!(back.classes, profile.classes);
        assert!(!back.is_empty());
        // `save` stamped the write time, so a reload seeds fresh state.
        assert!(back.saved_unix.is_some(), "save must stamp saved_unix");
        assert!(back.age_secs() < 3600.0, "age {}", back.age_secs());
        // The atomic rewrite leaves no temp file behind.
        assert!(!dir.join("profile.json.tmp").exists(), "temp file must be renamed away");
        // Corrupt file still fails hard, with the path named.
        std::fs::write(&path, "{not json").unwrap();
        let err = CostProfile::load(&path).unwrap_err();
        assert!(err.contains("profile.json"), "{err}");
        // A version mismatch is lenient: empty profile + warning, so an
        // old file never blocks serving (regression — this used to Err).
        std::fs::write(&path, r#"{"version": 99, "classes": {}}"#).unwrap();
        let (old, warn) = CostProfile::load(&path).unwrap();
        assert!(old.is_empty(), "mismatched version must seed nothing");
        let warn = warn.expect("mismatch must carry a warning");
        assert!(warn.contains("version 99"), "{warn}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Staleness decay tiers: fresh profiles seed everything, day-old
    /// ones keep only the class-wide mean, week-old (or unstamped) ones
    /// seed nothing.
    #[test]
    fn cost_snapshot_decay_tiers() {
        let snap = CostSnapshot { global: Some(0.01), buckets: vec![None, Some(0.02)] };
        let fresh = snap.decayed(10.0);
        assert_eq!(fresh, snap, "young state survives untouched");
        let day_old = snap.decayed(CostSnapshot::BUCKET_TTL_SECS + 1.0);
        assert_eq!(day_old.global, Some(0.01), "global mean survives a day");
        assert!(day_old.buckets.iter().all(|b| b.is_none()), "buckets expire after a day");
        let week_old = snap.decayed(CostSnapshot::GLOBAL_TTL_SECS + 1.0);
        assert!(week_old.is_empty(), "everything expires after a week");
        assert!(snap.decayed(f64::INFINITY).is_empty(), "unknown age seeds nothing");
        assert!(snap.decayed(f64::NAN).is_empty(), "garbage age seeds nothing");
        // The unstamped-profile age really is unknown.
        let p = CostProfile::default();
        assert_eq!(p.age_secs(), f64::INFINITY);
    }

    /// Delta books: attempts partition, NaN-safe means, and the
    /// per-worker → run-total merge.
    #[test]
    fn delta_metrics_rates_and_merge() {
        let empty = DeltaMetrics::default();
        assert_eq!(empty.attempts(), 0);
        assert!(empty.hit_rate().is_nan(), "no attempts ⇒ NaN, not 0/0 panic");
        assert!(empty.mean_dirty_frac().is_nan());
        assert!(empty.mean_recomputed_frac().is_nan());
        let mut total = DeltaMetrics {
            hits: 3,
            full_cold: 1,
            dirty_frac_sum: 0.3,
            recomputed_frac_sum: 0.6,
            sticky_hits: 2,
            ..Default::default()
        };
        let other = DeltaMetrics {
            hits: 1,
            full_over_threshold: 2,
            not_applicable: 5,
            dirty_frac_sum: 0.5,
            recomputed_frac_sum: 0.2,
            sticky_retired: 1,
            ..Default::default()
        };
        total.merge(&other);
        assert_eq!(total.attempts(), 3 + 1 + 1 + 2);
        assert!((total.hit_rate() - 4.0 / 7.0).abs() < 1e-12);
        assert!((total.mean_dirty_frac() - 0.2).abs() < 1e-12);
        assert!((total.mean_recomputed_frac() - 0.2).abs() < 1e-12);
        assert_eq!(
            (total.not_applicable, total.sticky_hits, total.sticky_retired),
            (5, 2, 1)
        );
    }

    /// The sliding window reports counter growth over (roughly) its span,
    /// evicting stale snapshots while keeping the window-edge one, and
    /// degenerate windows read as 0 rates — never NaN.
    #[test]
    fn sliding_window_tracks_recent_growth() {
        let mut w = SlidingWindow::new(Duration::from_millis(100));
        assert_eq!(w.delta(), 0);
        assert_eq!(w.rate(), 0.0, "empty window must not be NaN");
        let t0 = Instant::now();
        w.record(t0, 10);
        assert_eq!(w.delta(), 0, "one snapshot is no delta");
        assert_eq!(w.rate(), 0.0);
        w.record(t0 + Duration::from_millis(50), 17);
        assert_eq!(w.delta(), 7);
        assert!((w.span_secs() - 0.05).abs() < 1e-9);
        assert!((w.rate() - 140.0).abs() < 1e-6);
        // Two window-lengths later the early snapshots are evicted; the
        // delta reflects only recent growth.
        w.record(t0 + Duration::from_millis(220), 20);
        w.record(t0 + Duration::from_millis(260), 26);
        assert_eq!(w.delta(), 26 - 17, "stale snapshots must be evicted");
        // A regressing counter (caller bug) saturates instead of wrapping.
        let mut r = SlidingWindow::new(Duration::from_millis(100));
        r.record(t0, 50);
        r.record(t0 + Duration::from_millis(10), 40);
        assert_eq!(r.delta(), 0);
    }
}
