//! Compile-once / execute-many functional execution.
//!
//! The paper's premise is compose-once, stream-forever: modules are
//! parametrized and wired a single time, then event batches flow through a
//! fixed dataflow with no per-inference setup. [`super::exec`] (the oracle)
//! does the opposite — it re-walks the op program, re-resolves quantized
//! weights, and allocates fresh token/feature vectors on every request.
//! This module splits that into:
//!
//! - [`ExecPlan`] — built **once** per network from a [`QuantizedNet`]:
//!   ops lowered to a flat step list with pre-resolved weight/requant
//!   references (no `Option` unwrapping on the hot path), weights laid out
//!   for cache-friendly inner loops (the FC matrix is stored transposed;
//!   pointwise loops run ci-outer/co-inner over the native `[ci][co]`
//!   rows), and per-step geometry / scratch-size descriptors.
//! - [`ExecCtx`] — a reusable per-worker buffer arena: double-buffered
//!   token/feature maps, a residual fork pool, the [`NeighborIndex`]
//!   rulebook scratch, and the int32 accumulators. After a warm-up
//!   inference sizes the buffers, steady-state execution performs **zero
//!   heap allocations** (enforced by `rust/tests/exec_plan.rs` with a
//!   counting allocator).
//!
//! Execution is bit-exact with [`super::exec::forward_i8`]: both paths run
//! the same integer kernels (`sparse::conv`), property-tested across random
//! networks and inputs in `rust/tests/exec_plan.rs`.

use super::exec::argmax;
use super::graph::Op;
use super::quant::QuantizedNet;
use crate::sparse::conv;
use crate::sparse::quant::Requant;
use crate::sparse::rulebook::NeighborIndex;
use crate::sparse::{Bitmap, SparseMap};

/// Pre-resolved weights for one step (cloned out of the `QuantizedNet` at
/// compile time so execution never touches `Option<QuantOpWeights>`).
#[derive(Clone, Debug)]
pub struct StepWeights {
    pub w: Vec<i8>,
    pub b: Vec<i32>,
    pub rq: Requant,
}

/// One lowered execution step. Weighted variants embed their weights —
/// resolving them is a compile-time, not a per-request, operation.
#[derive(Clone, Debug)]
pub enum StepKind {
    /// 1×1 pointwise conv.
    Conv1x1(StepWeights),
    /// Full k×k submanifold conv, stride 1 (the stem).
    ConvKxKS1 { k: usize, w: StepWeights },
    /// Full k×k sparse conv, stride 2.
    ConvKxKS2 { k: usize, w: StepWeights },
    /// Depthwise k×k submanifold conv, stride 1.
    DwConvS1 { k: usize, w: StepWeights },
    /// Depthwise k×k sparse conv, stride 2.
    DwConvS2 { k: usize, w: StepWeights },
    /// Push a copy of the stream for an identity shortcut.
    ResFork,
    /// Pop the shortcut and add it (saturating int8).
    ResAdd,
    /// Global average pool over tokens (map → int32 vector).
    GlobalPool,
    /// FC head; weights stored **transposed** (`wt[co * cin + ci]`).
    Fc(StepWeights),
}

/// One step plus its geometry descriptor (input/output spatial size and
/// channel counts — `cout` doubles as the accumulator scratch size).
#[derive(Clone, Debug)]
pub struct PlanStep {
    pub kind: StepKind,
    pub in_w: usize,
    pub in_h: usize,
    pub cin: usize,
    pub out_w: usize,
    pub out_h: usize,
    pub cout: usize,
}

/// A compiled execution plan: build once per network, execute per request
/// through a reusable [`ExecCtx`].
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub steps: Vec<PlanStep>,
    /// Scale mapping f32 input → int8 (from calibration).
    pub input_scale: f32,
    /// Expected input geometry.
    pub in_w: usize,
    pub in_h: usize,
    pub cin: usize,
    /// Logit arity of the FC head.
    pub n_classes: usize,
    /// Largest accumulator any step needs (scratch-size descriptor).
    pub max_cout: usize,
    /// Deepest simultaneous residual-fork nesting.
    pub fork_depth: usize,
}

impl ExecPlan {
    /// Lower a quantized network into a flat step list. Panics on a
    /// malformed network (missing quantized weights, unbalanced residual
    /// forks, or a program that does not end in `GlobalPool → Fc`) — the
    /// same conditions the oracle would panic on mid-request, surfaced at
    /// compile time instead.
    pub fn compile(qnet: &QuantizedNet) -> ExecPlan {
        let spec = &qnet.spec;
        let ops = spec.ops();
        assert!(
            matches!(ops.last(), Some(Op::Fc { .. })),
            "ExecPlan requires a classification network ending in an FC head"
        );
        let weights_of = |i: usize| -> StepWeights {
            let q = qnet.per_op[i]
                .as_ref()
                .unwrap_or_else(|| panic!("op {i} has no quantized weights"));
            StepWeights { w: q.w.clone(), b: q.b.clone(), rq: q.rq }
        };
        let mut steps = Vec::with_capacity(ops.len());
        let (mut w, mut h) = (spec.w, spec.h);
        let mut c = spec.cin;
        let mut depth = 0usize;
        let mut fork_depth = 0usize;
        let mut max_cout = 0usize;
        for (i, op) in ops.iter().enumerate() {
            let (in_w, in_h, cin) = (w, h, c);
            let kind = match *op {
                Op::Conv1x1 { cout, .. } => {
                    c = cout;
                    StepKind::Conv1x1(weights_of(i))
                }
                Op::ConvKxK { k, cout, stride, .. } => {
                    c = cout;
                    if stride == 1 {
                        StepKind::ConvKxKS1 { k, w: weights_of(i) }
                    } else {
                        w = (w + 1) / 2;
                        h = (h + 1) / 2;
                        StepKind::ConvKxKS2 { k, w: weights_of(i) }
                    }
                }
                Op::DwConv { k, stride, .. } => {
                    if stride == 1 {
                        StepKind::DwConvS1 { k, w: weights_of(i) }
                    } else {
                        w = (w + 1) / 2;
                        h = (h + 1) / 2;
                        StepKind::DwConvS2 { k, w: weights_of(i) }
                    }
                }
                Op::ResFork => {
                    depth += 1;
                    fork_depth = fork_depth.max(depth);
                    StepKind::ResFork
                }
                Op::ResAdd => {
                    assert!(depth > 0, "ResAdd without matching ResFork at op {i}");
                    depth -= 1;
                    StepKind::ResAdd
                }
                Op::GlobalPool { .. } => StepKind::GlobalPool,
                Op::Fc { cin, cout } => {
                    let q = qnet.per_op[i]
                        .as_ref()
                        .unwrap_or_else(|| panic!("FC op {i} has no quantized weights"));
                    assert_eq!(q.w.len(), cin * cout, "FC weight shape mismatch");
                    // Transpose to `wt[co * cin + ci]` so each logit's dot
                    // product walks one contiguous row.
                    let mut wt = vec![0i8; cin * cout];
                    for ci in 0..cin {
                        for co in 0..cout {
                            wt[co * cin + ci] = q.w[ci * cout + co];
                        }
                    }
                    c = cout;
                    StepKind::Fc(StepWeights { w: wt, b: q.b.clone(), rq: q.rq })
                }
            };
            max_cout = max_cout.max(c);
            steps.push(PlanStep { kind, in_w, in_h, cin, out_w: w, out_h: h, cout: c });
        }
        assert_eq!(depth, 0, "unbalanced ResFork/ResAdd");
        ExecPlan {
            steps,
            input_scale: qnet.input_scale,
            in_w: spec.w,
            in_h: spec.h,
            cin: spec.cin,
            n_classes: spec.n_classes,
            max_cout,
            fork_depth,
        }
    }

    /// Run the plan over a float input, reusing `ctx`'s arena; returns the
    /// int32 logits (borrowed from the context — copy them out if they must
    /// outlive the next execution).
    ///
    /// Only the channel count is checked (matching the oracle,
    /// [`super::exec::forward_i8`]): every kernel derives its geometry from
    /// the input map, so off-spec resolutions execute fine — the plan's
    /// `in_w`/`in_h` and per-step descriptors are the *expected* geometry,
    /// for sizing and diagnostics.
    pub fn execute<'c>(&self, ctx: &'c mut ExecCtx, input: &SparseMap<f32>) -> &'c [i32] {
        assert_eq!(input.c, self.cin, "input channels mismatch");
        quantize_into(self.input_scale, input, &mut ctx.cur);
        ctx.fork_top = 0;
        for step in &self.steps {
            match step.kind {
                StepKind::Conv1x1(ref sw) => {
                    conv::conv1x1_i8_into(
                        &ctx.cur,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                }
                StepKind::ConvKxKS1 { k, w: ref sw } => {
                    conv::conv_kxk_s1_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                }
                StepKind::ConvKxKS2 { k, w: ref sw } => {
                    conv::conv_kxk_s2_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.ds,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                }
                StepKind::DwConvS1 { k, w: ref sw } => {
                    conv::dwconv_kxk_s1_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                }
                StepKind::DwConvS2 { k, w: ref sw } => {
                    conv::dwconv_kxk_s2_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.ds,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                }
                StepKind::ResFork => {
                    if ctx.forks.len() == ctx.fork_top {
                        ctx.forks.push(SparseMap::empty(0, 0, 0));
                    }
                    let top = ctx.fork_top;
                    ctx.forks[top].copy_from(&ctx.cur);
                    ctx.fork_top += 1;
                }
                StepKind::ResAdd => {
                    let top = ctx.fork_top.checked_sub(1).expect("ResAdd without ResFork");
                    ctx.fork_top = top;
                    conv::residual_add_i8_inplace(&mut ctx.cur, &ctx.forks[top]);
                }
                StepKind::GlobalPool => {
                    conv::global_avg_pool_i8_into(&ctx.cur, &mut ctx.acc64, &mut ctx.pooled);
                }
                StepKind::Fc(ref sw) => {
                    conv::fc_i8_t_into(&ctx.pooled, &sw.w, &sw.b, step.cout, &mut ctx.logits);
                }
            }
        }
        &ctx.logits
    }

    /// Classify: execute and argmax the logits.
    pub fn classify(&self, ctx: &mut ExecCtx, input: &SparseMap<f32>) -> usize {
        argmax(self.execute(ctx, input))
    }
}

/// Quantize a float input map into `out` with the network's input scale —
/// the arena variant of [`super::exec::quantize_input`].
fn quantize_into(scale: f32, input: &SparseMap<f32>, out: &mut SparseMap<i8>) {
    out.reset(input.w, input.h, input.c);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.feats.len());
    for &v in &input.feats {
        out.feats.push(((v / scale).round() as i32).clamp(-128, 127) as i8);
    }
}

/// Per-worker execution context: the buffer arena a plan executes through.
/// Create once (cheap — all buffers start empty), reuse for every request;
/// the first execution sizes the buffers and subsequent ones run
/// allocation-free. A context is plan-agnostic: it can be shared across
/// plans (buffers regrow as needed).
#[derive(Debug)]
pub struct ExecCtx {
    /// Double-buffered token/feature maps (current layer input / output).
    cur: SparseMap<i8>,
    next: SparseMap<i8>,
    /// Residual shortcut pool, `fork_top` slots live.
    forks: Vec<SparseMap<i8>>,
    fork_top: usize,
    /// Rulebook scratch: dense coordinate → token-index grid.
    idx: NeighborIndex,
    /// Stride-2 downsample bitmap scratch.
    ds: Bitmap,
    /// int32 accumulator (sized to the plan's `max_cout`).
    acc: Vec<i32>,
    /// i64 pooling accumulator.
    acc64: Vec<i64>,
    /// Pooled vector and logits.
    pooled: Vec<i32>,
    logits: Vec<i32>,
}

impl ExecCtx {
    pub fn new() -> ExecCtx {
        ExecCtx {
            cur: SparseMap::empty(0, 0, 0),
            next: SparseMap::empty(0, 0, 0),
            forks: Vec::new(),
            fork_top: 0,
            idx: NeighborIndex::new(),
            ds: Bitmap::new(0, 0),
            acc: Vec::new(),
            acc64: Vec::new(),
            pooled: Vec::new(),
            logits: Vec::new(),
        }
    }
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::exec::{classify_i8, forward_i8};
    use crate::model::quant::quantize_network;
    use crate::model::weights::FloatWeights;
    use crate::model::NetworkSpec;
    use crate::util::Rng;

    fn small_input(seed: u64) -> SparseMap<f32> {
        let p = DatasetProfile::n_mnist();
        let mut rng = Rng::new(seed);
        let es = p.sample(seed as usize % p.n_classes, &mut rng);
        histogram2_norm(&es, p.w, p.h, 8.0)
    }

    fn tiny_qnet(seed: u64) -> QuantizedNet {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, seed);
        let calib: Vec<SparseMap<f32>> = (0..3).map(small_input).collect();
        quantize_network(&spec, &w, &calib)
    }

    #[test]
    fn plan_structure_mirrors_ops() {
        let qnet = tiny_qnet(1);
        let plan = ExecPlan::compile(&qnet);
        assert_eq!(plan.steps.len(), qnet.spec.ops().len());
        assert_eq!(plan.n_classes, 5);
        assert_eq!(plan.fork_depth, 1); // tiny has one residual block
        assert!(plan.max_cout >= 8);
        // Geometry chains: each step's input is the previous step's output.
        for pair in plan.steps.windows(2) {
            assert_eq!((pair[0].out_w, pair[0].out_h), (pair[1].in_w, pair[1].in_h));
        }
        // The stride-2 block halves resolution exactly once in tiny.
        let last = plan.steps.last().unwrap();
        assert_eq!((last.out_w, last.out_h), (17, 17));
    }

    #[test]
    fn plan_execution_matches_oracle_logits() {
        let qnet = tiny_qnet(7);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        for s in 20..26u64 {
            let input = small_input(s);
            let want = forward_i8(&qnet, &input);
            let got = plan.execute(&mut ctx, &input).to_vec();
            assert_eq!(got, want, "seed {s}");
            assert_eq!(plan.classify(&mut ctx, &input), classify_i8(&qnet, &input));
        }
    }

    #[test]
    fn context_is_reusable_across_plans() {
        let qa = tiny_qnet(3);
        let qb = tiny_qnet(4);
        let pa = ExecPlan::compile(&qa);
        let pb = ExecPlan::compile(&qb);
        let mut ctx = ExecCtx::new();
        let input = small_input(9);
        // Interleave two plans through one context: no cross-talk.
        for _ in 0..2 {
            assert_eq!(pa.execute(&mut ctx, &input).to_vec(), forward_i8(&qa, &input));
            assert_eq!(pb.execute(&mut ctx, &input).to_vec(), forward_i8(&qb, &input));
        }
    }

    #[test]
    fn empty_input_classifies_without_panic() {
        let qnet = tiny_qnet(5);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        let empty: SparseMap<f32> = SparseMap::empty(34, 34, 2);
        let got = plan.execute(&mut ctx, &empty).to_vec();
        assert_eq!(got, forward_i8(&qnet, &empty));
    }
}
