//! MinkowskiEngine-style gather–scatter execution of submanifold
//! convolution — the stand-in for the paper's "GPU sparse" baseline
//! (Fig. 14).
//!
//! The library builds a *rulebook*: for every kernel offset it collects the
//! (input index, output index) pairs whose coordinates are related by that
//! offset, then performs one gathered GEMM per offset ("k0–k8 launches" in
//! the paper's Fig. 3 discussion). At batch size 1 the per-offset launch
//! and hash-map overhead dominates — the effect the paper observes on the
//! Jetson (§4.4: "the latency performance of sparse GPU implementation lags
//! behind the dense GPU baseline").
//!
//! Numerics are identical to [`super::conv::conv_kxk_s1_f32`] (checked by
//! property test); the difference is the execution schedule, which the
//! returned [`RulebookStats`] quantifies for the platform model.

use super::map::SparseMap;
use super::token::Token;
use std::collections::HashMap;

/// Execution statistics used by the Fig. 14 platform model.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RulebookStats {
    /// Coordinate hash-map insertions (one per input token).
    pub hash_inserts: usize,
    /// Coordinate hash-map probes (one per (token, offset) pair).
    pub hash_probes: usize,
    /// Kernel "launches" (one gathered GEMM per nonempty offset).
    pub launches: usize,
    /// Total gathered rows across launches (Σ rulebook pair counts).
    pub gathered_rows: usize,
    /// MACs actually performed.
    pub macs: usize,
}

/// Dense coordinate → token-index lookup, reused across layers — the
/// execution engine's "rulebook scratch". One O(nnz) rebuild per layer
/// replaces a hash probe (rulebook) or binary search (`SparseMap::find`)
/// per (token, offset) pair, and the grid storage is reused so steady-state
/// rebuilds never touch the heap. Entries store `index + 1`; a zero-filled
/// grid means "empty".
#[derive(Debug, Default)]
pub struct NeighborIndex {
    grid: Vec<u32>,
    w: usize,
    h: usize,
}

impl NeighborIndex {
    pub fn new() -> NeighborIndex {
        NeighborIndex { grid: Vec::new(), w: 0, h: 0 }
    }

    /// Point the index at `m`'s tokens, reusing the grid storage.
    pub fn build<T>(&mut self, m: &SparseMap<T>) {
        self.w = m.w;
        self.h = m.h;
        self.grid.clear();
        self.grid.resize(m.w * m.h, 0);
        for (i, t) in m.tokens.iter().enumerate() {
            self.grid[t.y as usize * m.w + t.x as usize] = i as u32 + 1;
        }
    }

    /// Token index at `(x, y)`, if occupied.
    #[inline]
    pub fn find(&self, x: usize, y: usize) -> Option<usize> {
        debug_assert!(x < self.w && y < self.h, "({x},{y}) outside {}×{}", self.w, self.h);
        match self.grid[y * self.w + x] {
            0 => None,
            i => Some(i as usize - 1),
        }
    }
}

/// Rulebook for one layer: per kernel offset, the (in, out) index pairs.
pub struct Rulebook {
    pub k: usize,
    pub pairs: Vec<Vec<(u32, u32)>>,
    pub stats: RulebookStats,
}

/// Build the stride-1 submanifold rulebook (output tokens = input tokens).
pub fn build_rulebook_s1(input: &SparseMap<f32>, k: usize) -> Rulebook {
    let u = (k - 1) as isize / 2;
    let mut stats = RulebookStats::default();
    let mut coord_to_idx: HashMap<(u16, u16), u32> = HashMap::with_capacity(input.nnz() * 2);
    for (i, t) in input.tokens.iter().enumerate() {
        coord_to_idx.insert((t.x, t.y), i as u32);
        stats.hash_inserts += 1;
    }
    let mut pairs = vec![Vec::new(); k * k];
    for (oi, t) in input.tokens.iter().enumerate() {
        for dy in 0..k as isize {
            for dx in 0..k as isize {
                let ix = t.x as isize + dx - u;
                let iy = t.y as isize + dy - u;
                stats.hash_probes += 1;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                if let Some(&ii) = coord_to_idx.get(&(ix as u16, iy as u16)) {
                    pairs[(dy * k as isize + dx) as usize].push((ii, oi as u32));
                }
            }
        }
    }
    Rulebook { k, pairs, stats }
}

/// Execute a full k×k submanifold conv via the rulebook: one gathered GEMM
/// per nonempty offset, scattered into the output. Weights laid out as in
/// `conv::conv_kxk_s1_f32`.
pub fn execute_s1(
    input: &SparseMap<f32>,
    rb: &mut Rulebook,
    w: &[f32],
    bias: &[f32],
    cout: usize,
) -> SparseMap<f32> {
    let cin = input.c;
    let k = rb.k;
    assert_eq!(w.len(), k * k * cin * cout);
    let mut out = SparseMap::empty(input.w, input.h, cout);
    out.tokens = input.tokens.clone();
    out.feats = vec![0f32; out.tokens.len() * cout];
    // Initialize with bias.
    for i in 0..out.tokens.len() {
        out.feats[i * cout..(i + 1) * cout].copy_from_slice(bias);
    }
    for (off, pairs) in rb.pairs.iter().enumerate() {
        if pairs.is_empty() {
            continue;
        }
        rb.stats.launches += 1;
        rb.stats.gathered_rows += pairs.len();
        let wbase = off * cin * cout;
        // Gather → GEMM → scatter (modelled in one pass; the schedule, not
        // the fusion, is what the stats capture).
        for &(ii, oi) in pairs {
            let f = input.feat(ii as usize);
            let ob = oi as usize * cout;
            for ci in 0..cin {
                let a = f[ci];
                let wrow = wbase + ci * cout;
                for co in 0..cout {
                    out.feats[ob + co] += a * w[wrow + co];
                }
            }
            rb.stats.macs += cin * cout;
        }
    }
    out
}

/// Build + execute a stride-2 sparse conv via rulebook (coordinates
/// re-derived with the s×s grid rule, as MinkowskiEngine's generative
/// stride does for even kernels — matching `conv::conv_kxk_s2_f32`).
pub fn conv_s2_rulebook(
    input: &SparseMap<f32>,
    k: usize,
    w: &[f32],
    bias: &[f32],
    cout: usize,
    stats: &mut RulebookStats,
) -> SparseMap<f32> {
    let cin = input.c;
    let pad = (k - 1) as isize / 2;
    let mut coord_to_idx: HashMap<(u16, u16), u32> = HashMap::with_capacity(input.nnz() * 2);
    for (i, t) in input.tokens.iter().enumerate() {
        coord_to_idx.insert((t.x, t.y), i as u32);
        stats.hash_inserts += 1;
    }
    let out_tokens: Vec<Token> = super::conv::downsample_tokens(&input.bitmap());
    let ow = (input.w + 1) / 2;
    let oh = (input.h + 1) / 2;
    let mut pairs = vec![Vec::new(); k * k];
    for (oi, t) in out_tokens.iter().enumerate() {
        for dy in 0..k as isize {
            for dx in 0..k as isize {
                let ix = t.x as isize * 2 + dx - pad;
                let iy = t.y as isize * 2 + dy - pad;
                stats.hash_probes += 1;
                if ix < 0 || iy < 0 || ix as usize >= input.w || iy as usize >= input.h {
                    continue;
                }
                if let Some(&ii) = coord_to_idx.get(&(ix as u16, iy as u16)) {
                    pairs[(dy * k as isize + dx) as usize].push((ii, oi as u32));
                }
            }
        }
    }
    let mut out = SparseMap::empty(ow, oh, cout);
    out.tokens = out_tokens;
    out.feats = vec![0f32; out.tokens.len() * cout];
    for i in 0..out.tokens.len() {
        out.feats[i * cout..(i + 1) * cout].copy_from_slice(bias);
    }
    for (off, ps) in pairs.iter().enumerate() {
        if ps.is_empty() {
            continue;
        }
        stats.launches += 1;
        stats.gathered_rows += ps.len();
        let wbase = off * cin * cout;
        for &(ii, oi) in ps {
            let f = input.feat(ii as usize);
            let ob = oi as usize * cout;
            for ci in 0..cin {
                let a = f[ci];
                let wrow = wbase + ci * cout;
                for co in 0..cout {
                    out.feats[ob + co] += a * w[wrow + co];
                }
            }
            stats.macs += cin * cout;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::conv::{conv_kxk_s1_f32, conv_kxk_s2_f32, Act};
    use crate::sparse::map::random_map;
    use crate::util::propcheck::check;

    #[test]
    fn rulebook_s1_matches_reference() {
        check("rulebook s1 == functional conv", 48, |g| {
            let w = g.usize(3, 12);
            let h = g.usize(3, 12);
            let cin = g.usize(1, 3);
            let cout = g.usize(1, 3);
            let k = 3;
            let m = random_map(g.rng(), w, h, cin, 0.3);
            let wt: Vec<f32> = (0..k * k * cin * cout).map(|_| g.f64() as f32 - 0.5).collect();
            let b: Vec<f32> = (0..cout).map(|_| g.f64() as f32).collect();
            let mut rb = build_rulebook_s1(&m, k);
            let got = execute_s1(&m, &mut rb, &wt, &b, cout);
            let want = conv_kxk_s1_f32(&m, k, &wt, &b, cout, Act::None);
            assert_eq!(got.tokens, want.tokens);
            for (a, e) in got.feats.iter().zip(&want.feats) {
                assert!((a - e).abs() < 1e-4, "{a} vs {e}");
            }
        });
    }

    #[test]
    fn rulebook_s2_matches_reference() {
        check("rulebook s2 == functional conv", 48, |g| {
            let w = g.usize(4, 12);
            let h = g.usize(4, 12);
            let cin = g.usize(1, 3);
            let cout = g.usize(1, 3);
            let k = 3;
            let m = random_map(g.rng(), w, h, cin, 0.3);
            let wt: Vec<f32> = (0..k * k * cin * cout).map(|_| g.f64() as f32 - 0.5).collect();
            let b: Vec<f32> = (0..cout).map(|_| g.f64() as f32).collect();
            let mut stats = RulebookStats::default();
            let got = conv_s2_rulebook(&m, k, &wt, &b, cout, &mut stats);
            let want = conv_kxk_s2_f32(&m, k, &wt, &b, cout, Act::None);
            assert_eq!(got.tokens, want.tokens);
            for (a, e) in got.feats.iter().zip(&want.feats) {
                assert!((a - e).abs() < 1e-4);
            }
        });
    }

    /// The grid index must agree with the binary-search `find` on every
    /// coordinate, including across rebuilds with different geometry.
    #[test]
    fn neighbor_index_matches_map_find() {
        check("NeighborIndex == SparseMap::find", 64, |g| {
            let mut idx = NeighborIndex::new();
            for _ in 0..2 {
                let w = g.usize(1, 14);
                let h = g.usize(1, 14);
                let m = random_map(g.rng(), w, h, 1, 0.3);
                idx.build(&m);
                for y in 0..h {
                    for x in 0..w {
                        assert_eq!(idx.find(x, y), m.find(x as u16, y as u16), "({x},{y})");
                    }
                }
            }
        });
    }

    #[test]
    fn stats_counts_are_consistent() {
        let mut r = crate::util::Rng::new(9);
        let m = random_map(&mut r, 16, 16, 4, 0.25);
        let mut rb = build_rulebook_s1(&m, 3);
        assert_eq!(rb.stats.hash_inserts, m.nnz());
        assert_eq!(rb.stats.hash_probes, m.nnz() * 9);
        let w = vec![0.1f32; 9 * 4 * 4];
        let b = vec![0f32; 4];
        let _ = execute_s1(&m, &mut rb, &w, &b, 4);
        assert!(rb.stats.launches <= 9);
        let total_pairs: usize = rb.pairs.iter().map(|p| p.len()).sum();
        assert_eq!(rb.stats.gathered_rows, total_pairs);
        assert_eq!(rb.stats.macs, total_pairs * 4 * 4);
        // Center offset always pairs every token with itself.
        assert_eq!(rb.pairs[4].len(), m.nnz());
    }
}
