//! Coordinate tokens (paper Eqn. 1): `[.x, .y, .end]`.
//!
//! The `.end` flag only exists on the wire (hardware streams, `arch::stream`);
//! in-memory sparse maps store plain `(x, y)` pairs in strictly increasing
//! ravel order.

/// Spatial coordinate of a nonzero feature vector. `u16` bounds the spatial
/// resolution at 65k per side — far beyond any event camera (paper max is
/// 180×240 feature maps, commercial sensors 720×1280).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash, PartialOrd, Ord)]
pub struct Token {
    /// Row (y first so derived `Ord` equals ravel order).
    pub y: u16,
    /// Column.
    pub x: u16,
}

impl Token {
    pub fn new(x: u16, y: u16) -> Self {
        Token { x, y }
    }

    /// Ravel (stream) order: `y * width + x`.
    #[inline]
    pub fn ravel(&self, width: usize) -> usize {
        self.y as usize * width + self.x as usize
    }
}

/// Free-function ravel for raw coordinates.
#[inline]
pub fn ravel(x: usize, y: usize, width: usize) -> usize {
    y * width + x
}

/// Check the strict-ordering invariant of Eqn. 1.
pub fn is_strictly_ordered(tokens: &[Token], width: usize) -> bool {
    tokens
        .windows(2)
        .all(|w| w[0].ravel(width) < w[1].ravel(width))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ord_matches_ravel() {
        let w = 17;
        let a = Token::new(16, 0);
        let b = Token::new(0, 1);
        assert!(a < b);
        assert!(a.ravel(w) < b.ravel(w));
        let c = Token::new(3, 5);
        let d = Token::new(4, 5);
        assert!(c < d);
    }

    #[test]
    fn strict_order_detects_dup_and_swap() {
        let w = 10;
        let ok = vec![Token::new(1, 0), Token::new(5, 0), Token::new(0, 1)];
        assert!(is_strictly_ordered(&ok, w));
        let dup = vec![Token::new(1, 0), Token::new(1, 0)];
        assert!(!is_strictly_ordered(&dup, w));
        let swap = vec![Token::new(5, 0), Token::new(1, 0)];
        assert!(!is_strictly_ordered(&swap, w));
    }
}
