//! Binary container for generated event datasets — the bridge from the
//! rust generator to the python training path (`esda gen-data` writes,
//! `python/compile/data.py` reads with `numpy.fromfile`).
//!
//! Layout (little-endian):
//! ```text
//! magic   u32 = 0x45534441 ("ESDA")
//! version u32 = 1
//! w, h    u32, u32
//! n       u32                     number of samples
//! then per sample:
//!   label    u32
//!   n_events u32
//!   events   n_events × { t_us u32, x u16, y u16, polarity u8, pad u8 }
//! ```

use super::aer::Event;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const MAGIC: u32 = 0x4553_4441;
pub const VERSION: u32 = 1;

/// One labelled recording.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub label: u32,
    pub events: Vec<Event>,
}

fn put_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
fn put_u16(w: &mut impl Write, v: u16) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}
fn get_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn get_u16(r: &mut impl Read) -> std::io::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

/// Write a dataset file.
pub fn write_dataset(path: &Path, w: usize, h: usize, samples: &[Sample]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = BufWriter::new(File::create(path)?);
    put_u32(&mut f, MAGIC)?;
    put_u32(&mut f, VERSION)?;
    put_u32(&mut f, w as u32)?;
    put_u32(&mut f, h as u32)?;
    put_u32(&mut f, samples.len() as u32)?;
    for s in samples {
        put_u32(&mut f, s.label)?;
        put_u32(&mut f, s.events.len() as u32)?;
        for e in &s.events {
            put_u32(&mut f, e.t_us)?;
            put_u16(&mut f, e.x)?;
            put_u16(&mut f, e.y)?;
            f.write_all(&[e.polarity as u8, 0u8])?;
        }
    }
    f.flush()
}

/// Bytes one serialized event occupies (t_us + x + y + polarity + pad).
const EVENT_BYTES: u64 = 10;
/// Bytes the fixed per-sample prefix occupies (label + n_events).
const SAMPLE_HEADER_BYTES: u64 = 8;
/// `Vec::with_capacity` clamp for header-supplied counts. Counts are
/// untrusted until the payload bytes actually arrive: a truncated or
/// corrupt file must not demand a multi-GB allocation up front. Reads
/// past the clamp grow the vec amortized as real bytes are decoded.
const MAX_PREALLOC: usize = 1 << 16;

fn invalid(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Read a dataset file. Returns (w, h, samples).
///
/// Header-supplied counts are validated against the file size before any
/// allocation sized from them: a header claiming more samples/events than
/// the remaining bytes could possibly hold is rejected as corrupt instead
/// of being trusted with a `Vec::with_capacity` reservation.
pub fn read_dataset(path: &Path) -> std::io::Result<(usize, usize, Vec<Sample>)> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut f = BufReader::new(file);
    let magic = get_u32(&mut f)?;
    if magic != MAGIC {
        return Err(invalid(format!("bad magic {magic:#x}")));
    }
    let version = get_u32(&mut f)?;
    if version != VERSION {
        return Err(invalid(format!("unsupported version {version}")));
    }
    let w = get_u32(&mut f)? as usize;
    let h = get_u32(&mut f)? as usize;
    let n = get_u32(&mut f)? as usize;
    // Every sample needs at least its fixed prefix on disk.
    if (n as u64).saturating_mul(SAMPLE_HEADER_BYTES) > file_len {
        return Err(invalid(format!(
            "header claims {n} sample(s) but the file is only {file_len} byte(s)"
        )));
    }
    let mut samples = Vec::with_capacity(n.min(MAX_PREALLOC));
    for i in 0..n {
        let label = get_u32(&mut f)?;
        let ne = get_u32(&mut f)? as usize;
        if (ne as u64).saturating_mul(EVENT_BYTES) > file_len {
            return Err(invalid(format!(
                "sample {i} claims {ne} event(s) but the file is only {file_len} byte(s)"
            )));
        }
        let mut events = Vec::with_capacity(ne.min(MAX_PREALLOC));
        for _ in 0..ne {
            let t_us = get_u32(&mut f)?;
            let x = get_u16(&mut f)?;
            let y = get_u16(&mut f)?;
            let mut pb = [0u8; 2];
            f.read_exact(&mut pb)?;
            events.push(Event { t_us, x, y, polarity: pb[0] != 0 });
        }
        samples.push(Sample { label, events });
    }
    Ok((w, h, samples))
}

/// Generate and write a full train/test dataset for a profile:
/// `n_per_class` train + `n_per_class_test` test samples per class.
/// Returns the two file paths.
pub fn generate_dataset_files(
    profile: &super::DatasetProfile,
    out_dir: &Path,
    n_per_class: usize,
    n_per_class_test: usize,
    seed: u64,
) -> std::io::Result<(std::path::PathBuf, std::path::PathBuf)> {
    let mut rng = crate::util::Rng::new(seed);
    let make = |n: usize, rng: &mut crate::util::Rng| -> Vec<Sample> {
        let mut out = Vec::new();
        for class in 0..profile.n_classes {
            for _ in 0..n {
                out.push(Sample {
                    label: class as u32,
                    events: profile.sample(class, rng),
                });
            }
        }
        out
    };
    let train = make(n_per_class, &mut rng);
    let test = make(n_per_class_test, &mut rng);
    let train_path = out_dir.join(format!("{}_train.esda", profile.name));
    let test_path = out_dir.join(format!("{}_test.esda", profile.name));
    write_dataset(&train_path, profile.w, profile.h, &train)?;
    write_dataset(&test_path, profile.w, profile.h, &test)?;
    Ok((train_path, test_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::DatasetProfile;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("esda_io_test");
        let path = dir.join("t.esda");
        let samples = vec![
            Sample {
                label: 3,
                events: vec![
                    Event { t_us: 10, x: 1, y: 2, polarity: true },
                    Event { t_us: 20, x: 3, y: 4, polarity: false },
                ],
            },
            Sample { label: 0, events: vec![] },
        ];
        write_dataset(&path, 64, 48, &samples).unwrap();
        let (w, h, back) = read_dataset(&path).unwrap();
        assert_eq!((w, h), (64, 48));
        assert_eq!(back, samples);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("esda_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.esda");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(read_dataset(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A corrupt header claiming astronomically many samples/events must be
    /// rejected from the file-size check, not trusted with a header-sized
    /// `Vec::with_capacity` (a truncated file could otherwise demand tens
    /// of GB before the first payload byte is read).
    #[test]
    fn rejects_truncated_file_without_header_sized_alloc() {
        let dir = std::env::temp_dir().join(format!("esda_io_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // Valid magic/version/geometry, but n = u32::MAX and no payload.
        let path = dir.join("huge_n.esda");
        let mut bytes = Vec::new();
        for v in [MAGIC, VERSION, 64, 48, u32::MAX] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let err = read_dataset(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert!(err.to_string().contains("sample"), "{err}");

        // One sample whose event count (~5 GB worth) exceeds the file size.
        let path = dir.join("huge_ne.esda");
        let mut bytes = Vec::new();
        for v in [MAGIC, VERSION, 64, 48, 1, /* label */ 0, /* n_events */ 0x2000_0000] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let err = read_dataset(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert!(err.to_string().contains("event"), "{err}");

        // A file truncated mid-events still errors (cleanly, via read_exact).
        let path = dir.join("cut.esda");
        let mut bytes = Vec::new();
        for v in [MAGIC, VERSION, 64, 48, 1, 0, 2] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[1, 2, 3]); // 3 of the 20 event bytes
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_dataset(&path).is_err());

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn generate_files_balanced_labels() {
        let dir = std::env::temp_dir().join(format!("esda_io_gen_{}", std::process::id()));
        let p = DatasetProfile::n_mnist();
        let (train, test) = generate_dataset_files(&p, &dir, 2, 1, 7).unwrap();
        let (_, _, ts) = read_dataset(&train).unwrap();
        let (_, _, vs) = read_dataset(&test).unwrap();
        assert_eq!(ts.len(), p.n_classes * 2);
        assert_eq!(vs.len(), p.n_classes);
        for c in 0..p.n_classes as u32 {
            assert_eq!(ts.iter().filter(|s| s.label == c).count(), 2);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
