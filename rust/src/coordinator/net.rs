//! Socket ingestion: the multi-tenant network front door.
//!
//! The paper's deployment model (§2.1) is a *live* AER stream arriving
//! over the PS host interface — modeled here after the Prophesee
//! EVT 2.1 / KV260 pipeline: producers push compact event packets over
//! UDP or TCP, the receiver lands them in DMA-style buffers that are
//! flushed downstream on **size or timeout** (whichever comes first),
//! and every packet carries a per-stream tenant identity so the serving
//! runtime can enforce per-tenant admission quotas and SLOs.
//!
//! ## Wire format
//!
//! One packet is the on-wire twin of the `.esda` sample record, all
//! fields little-endian:
//!
//! ```text
//! magic   u32  = NET_MAGIC
//! version u16  = NET_VERSION (2)
//! tenant  u16  index into the server's tenant table
//! label   u32  producer-asserted ground-truth class
//! model   u32  fleet model id (version >= 2 only)
//! n       u32  event count (<= MAX_PACKET_EVENTS)
//! n × [ t_us u32 | x u16 | y u16 | polarity u8 | pad u8 ]
//! ```
//!
//! Version 2 is a minor bump for fleet serving: it appends the `model`
//! field (the index of the served model the packet addresses). Version 1
//! packets — identical minus that field — still decode and land on
//! model 0, so pre-fleet producers keep working unmodified.
//!
//! Over **UDP** each datagram is exactly one packet (the event cap keeps
//! a full packet inside one 64 KiB datagram). Over **TCP** packets are
//! length-prefixed (`u32` byte length, then the packet) on a persistent
//! connection; each connection gets its own receive thread and DMA
//! buffer — per-stream identity as in EventFlow.
//!
//! ## Validation and error severity
//!
//! Per-packet validation reuses the ingest boundary's
//! [`validate_events`]/[`validate_geometry`]: a malformed or rejected
//! packet is a *recoverable* [`IngestError`] (datagram/frame boundaries
//! keep the stream aligned), tagged with the owning tenant whenever the
//! header parsed — the server skips it and counts it under
//! `ingest_rejects`. Only socket-level failures (bind errors, broken
//! receive loop) are fatal.

use super::ingest::{validate_events, validate_geometry, EventSource, IngestError};
use super::{SourcedRequest, UnsortedPolicy};
use crate::events::{io, Event};
use std::collections::VecDeque;
use std::io::Read;
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Packet magic ("ESNP"): distinct from the `.esda` container magic so a
/// file accidentally piped at a socket fails loudly at the first packet.
pub const NET_MAGIC: u32 = 0x4553_4e50;
/// Packet format version (v2 appended the fleet `model` field; v1
/// packets still decode — see the module docs).
pub const NET_VERSION: u16 = 2;
/// Fixed packet header bytes at the current version
/// (magic + version + tenant + label + model + n).
pub const PACKET_HEADER_BYTES: usize = 20;
/// Header bytes of a version-1 packet (no `model` field).
pub const PACKET_V1_HEADER_BYTES: usize = 16;
/// Serialized bytes per event record (same layout as `.esda`).
pub const PACKET_EVENT_BYTES: usize = 10;
/// Per-packet event cap: the largest count whose packet still fits one
/// 64 KiB UDP datagram (65507 payload bytes). TCP frames obey the same
/// cap so producers need one packetizer.
pub const MAX_PACKET_EVENTS: usize = (65507 - PACKET_HEADER_BYTES) / PACKET_EVENT_BYTES;

/// Checked little-endian header reads: the only way wire bytes become
/// integers here. Callers bound-check `b` before field extraction, and
/// widths are explicit — no `try_into().unwrap()`, no bare `as`.
fn le_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn le_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// A decoded packet, before boundary validation.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    pub tenant: u16,
    pub label: u32,
    /// Fleet model id (0 for version-1 packets, which predate fleets).
    pub model: u32,
    pub events: Vec<Event>,
}

/// Serialize one packet addressed at the default model (id 0) — the
/// single-model producer path. Panics if `events` exceeds
/// [`MAX_PACKET_EVENTS`] — producers must window their streams.
pub fn encode_packet(tenant: u16, label: u32, events: &[Event]) -> Vec<u8> {
    encode_packet_for(tenant, label, 0, events)
}

/// Serialize one packet addressed at fleet model `model`. Panics if
/// `events` exceeds [`MAX_PACKET_EVENTS`] — producers must window their
/// streams.
pub fn encode_packet_for(tenant: u16, label: u32, model: u32, events: &[Event]) -> Vec<u8> {
    assert!(
        events.len() <= MAX_PACKET_EVENTS,
        "packet holds {} events (cap {MAX_PACKET_EVENTS})",
        events.len()
    );
    let mut out = Vec::with_capacity(PACKET_HEADER_BYTES + events.len() * PACKET_EVENT_BYTES);
    out.extend_from_slice(&NET_MAGIC.to_le_bytes());
    out.extend_from_slice(&NET_VERSION.to_le_bytes());
    out.extend_from_slice(&tenant.to_le_bytes());
    out.extend_from_slice(&label.to_le_bytes());
    out.extend_from_slice(&model.to_le_bytes());
    // lint:allow(panic): the assert above bounds events.len() far below u32::MAX
    let count = u32::try_from(events.len()).expect("event count fits u32");
    out.extend_from_slice(&count.to_le_bytes());
    for e in events {
        out.extend_from_slice(&e.t_us.to_le_bytes());
        out.extend_from_slice(&e.x.to_le_bytes());
        out.extend_from_slice(&e.y.to_le_bytes());
        out.push(e.polarity as u8);
        out.push(0);
    }
    out
}

/// Decode one packet, trusting nothing: the event-count claim is checked
/// against the bytes actually present (the same remaining-bytes
/// discipline as the `.esda` reader) before any allocation sized from
/// it.
pub fn decode_packet(buf: &[u8]) -> Result<Packet, String> {
    if buf.len() < PACKET_V1_HEADER_BYTES {
        return Err(format!(
            "short packet: {} byte(s), header needs {PACKET_V1_HEADER_BYTES}",
            buf.len()
        ));
    }
    let magic = le_u32(buf, 0);
    if magic != NET_MAGIC {
        return Err(format!("bad magic {magic:#010x}"));
    }
    let version = le_u16(buf, 4);
    // v2 appended the model field; v1 packets decode with model 0.
    let (header, model) = match version {
        1 => (PACKET_V1_HEADER_BYTES, 0),
        2 if buf.len() >= PACKET_HEADER_BYTES => (PACKET_HEADER_BYTES, le_u32(buf, 12)),
        2 => {
            return Err(format!(
                "short v2 packet: {} byte(s), header needs {PACKET_HEADER_BYTES}",
                buf.len()
            ))
        }
        v => return Err(format!("unsupported packet version {v}")),
    };
    let tenant = le_u16(buf, 6);
    let label = le_u32(buf, 8);
    let ne = usize::try_from(le_u32(buf, header - 4)).map_err(|e| e.to_string())?;
    if ne > MAX_PACKET_EVENTS {
        return Err(format!("claims {ne} event(s) (cap {MAX_PACKET_EVENTS})"));
    }
    let need = header + ne * PACKET_EVENT_BYTES;
    if buf.len() != need {
        return Err(format!(
            "claims {ne} event(s) ({need} B) but the packet is {} byte(s)",
            buf.len()
        ));
    }
    let events =
        io::read_events(&mut &buf[header..], ne).map_err(|e| format!("event records: {e}"))?;
    Ok(Packet { tenant, label, model, events })
}

/// Tuning for a socket source.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Tenant-table size: packets naming a tenant `>= tenants` are
    /// rejected (recoverably) at the boundary.
    pub tenants: usize,
    /// Fleet-model-table size: packets naming a model `>= models` are
    /// rejected (recoverably) at the boundary. 1 for single-model
    /// servers (v1 packets always land on model 0).
    pub models: usize,
    /// Unsorted-events policy (default: sort — live capture paths can
    /// reorder events in flight, same rationale as `TailSource`).
    pub policy: UnsortedPolicy,
    /// DMA buffer flush threshold: a buffer holding this many decoded
    /// packets is handed downstream immediately.
    pub flush_count: usize,
    /// DMA buffer flush deadline: a non-empty buffer is handed
    /// downstream once its oldest packet has waited this long.
    pub flush_timeout: Duration,
    /// Receive-loop poll granularity (read timeouts, stop-flag checks).
    pub poll: Duration,
    /// `next_request` returns end-of-stream after this long without any
    /// flushed buffer arriving.
    pub idle_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            tenants: 1,
            models: 1,
            policy: UnsortedPolicy::Sort,
            flush_count: 32,
            flush_timeout: Duration::from_millis(2),
            poll: Duration::from_millis(1),
            idle_timeout: Duration::from_secs(2),
        }
    }
}

/// One boundary outcome: an admitted request, or a recoverable reject
/// the server should count.
type Item = Result<SourcedRequest, IngestError>;

/// DMA-style receive buffer: decoded packets accumulate here and the
/// whole buffer is handed downstream when it reaches `cap` packets *or*
/// its oldest packet has waited `timeout` — the size/latency trade the
/// KV260 PS interface makes in hardware.
struct DmaBuffer {
    cap: usize,
    timeout: Duration,
    buf: Vec<Item>,
    oldest: Option<Instant>,
}

impl DmaBuffer {
    fn new(cap: usize, timeout: Duration) -> DmaBuffer {
        DmaBuffer { cap: cap.max(1), timeout, buf: Vec::new(), oldest: None }
    }

    fn take(&mut self) -> Vec<Item> {
        self.oldest = None;
        std::mem::take(&mut self.buf)
    }

    /// Land one item; returns the full buffer when the size threshold
    /// trips.
    fn push(&mut self, item: Item, now: Instant) -> Option<Vec<Item>> {
        self.oldest.get_or_insert(now);
        self.buf.push(item);
        (self.buf.len() >= self.cap).then(|| self.take())
    }

    /// Returns the buffer when the oldest item has waited out the flush
    /// deadline.
    fn due(&mut self, now: Instant) -> Option<Vec<Item>> {
        match self.oldest {
            Some(t) if now.duration_since(t) >= self.timeout => Some(self.take()),
            _ => None,
        }
    }
}

/// Decode + boundary-validate one packet's bytes into an [`Item`].
/// `conn` is the carrying TCP connection's id when there is one: a
/// connection is a stable event stream, so its packets get a stream
/// identity of `tenant << 32 | conn` for sticky routing and delta
/// execution. Datagrams (`None`) have no connection, hence no stream.
fn item_from_bytes(
    buf: &[u8],
    what: &str,
    w: usize,
    h: usize,
    cfg: &NetConfig,
    conn: Option<u64>,
) -> Item {
    let pkt = match decode_packet(buf) {
        Ok(p) => p,
        Err(e) => return Err(IngestError::recoverable(format!("{what}: {e}"))),
    };
    let tenant = usize::from(pkt.tenant);
    if tenant >= cfg.tenants {
        return Err(IngestError::recoverable(format!(
            "{what}: unknown tenant {tenant} (front door has {})",
            cfg.tenants
        )));
    }
    let model = usize::try_from(pkt.model)
        .map_err(|_| IngestError::recoverable(format!("{what}: model {} > usize", pkt.model)))?;
    if model >= cfg.models {
        return Err(IngestError::recoverable(format!(
            "{what}: unknown model {model} (front door has {})",
            cfg.models
        ))
        .with_tenant(tenant));
    }
    let mut events = pkt.events;
    validate_events(&mut events, w, h, cfg.policy, what).map_err(|e| e.with_tenant(tenant))?;
    let label = usize::try_from(pkt.label)
        .map_err(|_| IngestError::recoverable(format!("{what}: label {} > usize", pkt.label)))?;
    let stream = conn.map(|c| ((tenant as u64) << 32) | (c & 0xffff_ffff));
    Ok(SourcedRequest { label, events, arrival: Instant::now(), tenant, model, stream })
}

/// A socket-backed [`EventSource`]: background receive threads land
/// packets in DMA buffers and flush them (on size or timeout) over a
/// channel the serving runtime's stage-1 thread drains.
pub struct NetSource {
    name: String,
    w: usize,
    h: usize,
    rx: Receiver<Vec<Item>>,
    pending: VecDeque<Item>,
    idle_timeout: Duration,
    limit: Option<usize>,
    emitted: usize,
    local_port: u16,
    // lint: atomic(relaxed): shutdown latch, only ever flipped false->true;
    // polling receive threads may observe it a poll interval late
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl NetSource {
    /// Bind a UDP socket on `port` (0 picks an ephemeral port — see
    /// [`NetSource::local_port`]) receiving one packet per datagram.
    /// `(w, h)` is the geometry every packet is validated against.
    pub fn udp(port: u16, w: usize, h: usize, cfg: NetConfig) -> Result<NetSource, IngestError> {
        validate_geometry(w, h, "udp source")?;
        let sock = UdpSocket::bind(("127.0.0.1", port))
            .map_err(|e| IngestError::fatal(format!("udp:{port}: bind: {e}")))?;
        let local_port = sock
            .local_addr()
            .map_err(|e| IngestError::fatal(format!("udp:{port}: {e}")))?
            .port();
        sock.set_read_timeout(Some(cfg.poll))
            .map_err(|e| IngestError::fatal(format!("udp:{port}: {e}")))?;
        let (tx, rx) = std::sync::mpsc::channel::<Vec<Item>>();
        let stop = Arc::new(AtomicBool::new(false));
        // lint: atomic(relaxed): shutdown latch (see `NetSource::stop`)
        let stop2 = Arc::clone(&stop);
        let idle_timeout = cfg.idle_timeout;
        let handle = std::thread::spawn(move || {
            // Lock-free thread: see the note in `serve_connection`.
            crate::util::lockcheck::debug_assert_no_locks_held("net udp receive");
            let mut dma = DmaBuffer::new(cfg.flush_count, cfg.flush_timeout);
            let mut buf = vec![0u8; 65536];
            loop {
                if stop2.load(Ordering::Relaxed) {
                    return;
                }
                match sock.recv(&mut buf) {
                    Ok(n) => {
                        let item = item_from_bytes(&buf[..n], "udp packet", w, h, &cfg, None);
                        if let Some(batch) = dma.push(item, Instant::now()) {
                            if tx.send(batch).is_err() {
                                return;
                            }
                        }
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut => {}
                    Err(e) => {
                        let fail = IngestError::fatal(format!("udp receive: {e}"));
                        let _ = tx.send(vec![Err(fail)]);
                        return;
                    }
                }
                if let Some(batch) = dma.due(Instant::now()) {
                    if tx.send(batch).is_err() {
                        return;
                    }
                }
            }
        });
        Ok(NetSource {
            name: format!("udp:{local_port}"),
            w,
            h,
            rx,
            pending: VecDeque::new(),
            idle_timeout,
            limit: None,
            emitted: 0,
            local_port,
            stop,
            handles: vec![handle],
        })
    }

    /// Bind a TCP listener on `port` (0 picks an ephemeral port)
    /// accepting length-prefixed packet streams; each connection gets
    /// its own receive thread and DMA buffer.
    pub fn tcp(port: u16, w: usize, h: usize, cfg: NetConfig) -> Result<NetSource, IngestError> {
        validate_geometry(w, h, "tcp source")?;
        let listener = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| IngestError::fatal(format!("tcp:{port}: bind: {e}")))?;
        let local_port = listener
            .local_addr()
            .map_err(|e| IngestError::fatal(format!("tcp:{port}: {e}")))?
            .port();
        listener
            .set_nonblocking(true)
            .map_err(|e| IngestError::fatal(format!("tcp:{port}: {e}")))?;
        let (tx, rx) = std::sync::mpsc::channel::<Vec<Item>>();
        let stop = Arc::new(AtomicBool::new(false));
        // lint: atomic(relaxed): shutdown latch (see `NetSource::stop`)
        let stop2 = Arc::clone(&stop);
        let idle_timeout = cfg.idle_timeout;
        let poll = cfg.poll;
        let handle = std::thread::spawn(move || loop {
            if stop2.load(Ordering::Relaxed) {
                return;
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    let (tx, stop, cfg) = (tx.clone(), Arc::clone(&stop2), cfg.clone());
                    std::thread::spawn(move || {
                        serve_connection(stream, &format!("tcp peer {peer}"), w, h, cfg, tx, stop)
                    });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    std::thread::sleep(poll)
                }
                Err(e) => {
                    let fail = IngestError::fatal(format!("tcp accept: {e}"));
                    let _ = tx.send(vec![Err(fail)]);
                    return;
                }
            }
        });
        Ok(NetSource {
            name: format!("tcp:{local_port}"),
            w,
            h,
            rx,
            pending: VecDeque::new(),
            idle_timeout,
            limit: None,
            emitted: 0,
            local_port,
            stop,
            handles: vec![handle],
        })
    }

    /// The port actually bound — useful with port 0 (tests, examples).
    pub fn local_port(&self) -> u16 {
        self.local_port
    }

    /// Cap the number of requests emitted (default: until idle timeout).
    pub fn with_limit(mut self, limit: usize) -> NetSource {
        self.limit = Some(limit);
        self
    }
}

impl Drop for NetSource {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

impl EventSource for NetSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn geometry(&self) -> (usize, usize) {
        (self.w, self.h)
    }

    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        if self.limit.is_some_and(|l| self.emitted >= l) {
            return Ok(None);
        }
        loop {
            match self.pending.pop_front() {
                Some(Ok(req)) => {
                    self.emitted += 1;
                    return Ok(Some(req));
                }
                Some(Err(e)) => return Err(e),
                None => {}
            }
            match self.rx.recv_timeout(self.idle_timeout) {
                Ok(batch) => self.pending.extend(batch),
                // Quiet past the idle window, or the receive loop is
                // gone with nothing queued: end of stream.
                Err(_) => return Ok(None),
            }
        }
    }
}

/// Per-connection receive loop: length-prefixed frames into this
/// connection's DMA buffer. A malformed frame poisons the framing, so it
/// is reported (recoverably) and the connection dropped; the listener
/// keeps serving other producers.
fn serve_connection(
    mut stream: TcpStream,
    what: &str,
    w: usize,
    h: usize,
    cfg: NetConfig,
    tx: Sender<Vec<Item>>,
    // lint: atomic(relaxed): shutdown latch (see `NetSource::stop`)
    stop: Arc<AtomicBool>,
) {
    // Receive threads never take coordinator locks: they speak to the
    // runtime only through the flush channel, so a stuck worker can
    // never wedge socket draining (asserted in debug builds).
    crate::util::lockcheck::debug_assert_no_locks_held("net serve_connection");
    if stream.set_read_timeout(Some(cfg.poll)).is_err() {
        return;
    }
    // Process-unique connection id: the low half of this connection's
    // packets' stream identity (see `item_from_bytes`).
    // lint: atomic(relaxed): fetch_add uniqueness needs no cross-id ordering
    static NEXT_CONN: AtomicU64 = AtomicU64::new(1);
    let conn = NEXT_CONN.fetch_add(1, Ordering::Relaxed);
    let frame_cap = PACKET_HEADER_BYTES + MAX_PACKET_EVENTS * PACKET_EVENT_BYTES;
    let mut dma = DmaBuffer::new(cfg.flush_count, cfg.flush_timeout);
    let flush = |dma: &mut DmaBuffer| {
        if let Some(batch) = dma.due(Instant::now()) {
            return tx.send(batch).is_ok();
        }
        true
    };
    loop {
        let mut len_buf = [0u8; 4];
        match read_full(&mut stream, &mut len_buf, &stop, &mut || flush(&mut dma)) {
            ReadOutcome::Full => {}
            ReadOutcome::CleanEof => break,
            ReadOutcome::Stopped | ReadOutcome::Failed => return,
        }
        // A u32 length always fits usize on supported targets; a
        // pathological one lands on MAX and fails the cap check below.
        let len = usize::try_from(u32::from_le_bytes(len_buf)).unwrap_or(usize::MAX);
        if len < PACKET_V1_HEADER_BYTES || len > frame_cap {
            let _ = tx.send(vec![Err(IngestError::recoverable(format!(
                "{what}: bad frame length {len} (connection dropped)"
            )))]);
            return;
        }
        let mut frame = vec![0u8; len];
        match read_full(&mut stream, &mut frame, &stop, &mut || flush(&mut dma)) {
            ReadOutcome::Full => {}
            // EOF mid-frame: the producer died between length and
            // payload — report it like a truncated tail.
            ReadOutcome::CleanEof => {
                let _ = tx.send(vec![Err(IngestError::recoverable(format!(
                    "{what}: connection closed mid-frame"
                )))]);
                return;
            }
            ReadOutcome::Stopped | ReadOutcome::Failed => return,
        }
        let item = item_from_bytes(&frame, what, w, h, &cfg, Some(conn));
        if let Some(batch) = dma.push(item, Instant::now()) {
            if tx.send(batch).is_err() {
                return;
            }
        }
        if !flush(&mut dma) {
            return;
        }
    }
    // Clean close: hand over whatever the buffer still holds.
    let tail = dma.take();
    if !tail.is_empty() {
        let _ = tx.send(tail);
    }
}

enum ReadOutcome {
    /// The buffer was filled.
    Full,
    /// EOF before the first byte — a clean close at a frame boundary.
    CleanEof,
    /// The stop flag tripped or the flush callback lost its channel.
    Stopped,
    /// EOF mid-buffer or a hard IO error.
    Failed,
}

/// Fill `buf` from a read-timeout'd stream, running `tick` on every
/// timeout so the caller can flush DMA deadlines and observe shutdown.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    // lint: atomic(relaxed): shutdown latch (see `NetSource::stop`)
    stop: &AtomicBool,
    tick: &mut dyn FnMut() -> bool,
) -> ReadOutcome {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return ReadOutcome::Stopped;
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 { ReadOutcome::CleanEof } else { ReadOutcome::Failed }
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if !tick() {
                    return ReadOutcome::Stopped;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadOutcome::Failed,
        }
    }
    ReadOutcome::Full
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn ev(t: u32, x: u16, y: u16) -> Event {
        Event { t_us: t, x, y, polarity: true }
    }

    fn quick_cfg() -> NetConfig {
        NetConfig {
            tenants: 2,
            flush_count: 4,
            flush_timeout: Duration::from_millis(1),
            poll: Duration::from_millis(1),
            idle_timeout: Duration::from_millis(300),
            ..NetConfig::default()
        }
    }

    #[test]
    fn packet_roundtrips() {
        let events = vec![ev(1, 2, 3), ev(5, 4, 4)];
        let wire = encode_packet(1, 7, &events);
        assert_eq!(wire.len(), PACKET_HEADER_BYTES + 2 * PACKET_EVENT_BYTES);
        let pkt = decode_packet(&wire).unwrap();
        assert_eq!(pkt, Packet { tenant: 1, label: 7, model: 0, events: events.clone() });
        // A model-addressed packet carries the model id through.
        let wire = encode_packet_for(1, 7, 3, &events);
        let pkt = decode_packet(&wire).unwrap();
        assert_eq!(pkt, Packet { tenant: 1, label: 7, model: 3, events });
    }

    /// A version-1 packet (pre-fleet, 16-byte header, no model field)
    /// still decodes and lands on model 0 — producers that never heard
    /// of fleets keep working across the minor version bump.
    #[test]
    fn v1_packets_decode_as_model_zero() {
        let events = vec![ev(1, 2, 3)];
        let mut wire = Vec::new();
        wire.extend_from_slice(&NET_MAGIC.to_le_bytes());
        wire.extend_from_slice(&1u16.to_le_bytes());
        wire.extend_from_slice(&4u16.to_le_bytes());
        wire.extend_from_slice(&9u32.to_le_bytes());
        wire.extend_from_slice(&1u32.to_le_bytes());
        for e in &events {
            wire.extend_from_slice(&e.t_us.to_le_bytes());
            wire.extend_from_slice(&e.x.to_le_bytes());
            wire.extend_from_slice(&e.y.to_le_bytes());
            wire.push(1);
            wire.push(0);
        }
        assert_eq!(wire.len(), PACKET_V1_HEADER_BYTES + PACKET_EVENT_BYTES);
        let pkt = decode_packet(&wire).unwrap();
        assert_eq!(pkt, Packet { tenant: 4, label: 9, model: 0, events });
        // Truncating the v1 payload is still caught by the byte budget.
        assert!(decode_packet(&wire[..wire.len() - 1]).unwrap_err().contains("1 event(s)"));
    }

    /// Boundary regression for the checked wire casts: the extreme values
    /// of every narrow header field (tenant u16::MAX, label u32::MAX, the
    /// exact event-count cap) survive an encode/decode roundtrip bit-for-
    /// bit, and the decoded extremes widen into a `SourcedRequest` without
    /// truncation — the failure a bare `as` cast would hide.
    #[test]
    fn header_field_extremes_roundtrip_unclipped() {
        let events = vec![ev(u32::MAX, u16::MAX, u16::MAX)];
        let wire = encode_packet_for(u16::MAX, u32::MAX, u32::MAX, &events);
        let pkt = decode_packet(&wire).unwrap();
        assert_eq!(pkt, Packet { tenant: u16::MAX, label: u32::MAX, model: u32::MAX, events });

        // A packet at exactly the event cap decodes; one past it cannot
        // even be encoded (and a forged count is rejected by decode —
        // covered in `decode_rejects_malformed_packets`).
        let full = vec![ev(1, 1, 1); MAX_PACKET_EVENTS];
        let wire = encode_packet(0, 0, &full);
        assert!(wire.len() <= 65507, "cap must keep a packet in one datagram");
        assert_eq!(decode_packet(&wire).unwrap().events.len(), MAX_PACKET_EVENTS);

        // Widening through the ingest item: a max-tenant packet is
        // attributed to tenant 65535 (here: rejected as unknown, but with
        // the *untruncated* index in the message), never aliased to a
        // small tenant table slot.
        let cfg = NetConfig { tenants: 2, ..NetConfig::default() };
        let wire = encode_packet(u16::MAX, 3, &[ev(1, 1, 1)]);
        let err = item_from_bytes(&wire, "test", 8, 8, &cfg, None).unwrap_err();
        assert!(err.is_recoverable(), "{err}");
        assert!(err.to_string().contains("65535"), "{err}");

        // And a max-label packet from a known tenant lands with the label
        // intact after the u32 -> usize widening.
        let wire = encode_packet(1, u32::MAX, &[ev(1, 1, 1)]);
        let req = item_from_bytes(&wire, "test", 8, 8, &cfg, Some(9)).unwrap();
        assert_eq!(req.label, u32::MAX as usize);
        assert_eq!(req.tenant, 1);
        assert_eq!(req.model, 0, "encode_packet addresses the default model");

        // A max-model packet against a single-model front door is
        // rejected recoverably with the untruncated id, attributed to
        // its (known) tenant.
        let wire = encode_packet_for(1, 0, u32::MAX, &[ev(1, 1, 1)]);
        let err = item_from_bytes(&wire, "test", 8, 8, &cfg, None).unwrap_err();
        assert!(err.is_recoverable(), "{err}");
        assert!(err.to_string().contains("4294967295"), "{err}");
        assert_eq!(err.tenant(), Some(1));

        // With a fleet-sized front door the same packet's model id rides
        // through the widening intact.
        let fleet = NetConfig { tenants: 2, models: 3, ..NetConfig::default() };
        let wire = encode_packet_for(0, 2, 2, &[ev(1, 1, 1)]);
        let req = item_from_bytes(&wire, "test", 8, 8, &fleet, None).unwrap();
        assert_eq!(req.model, 2);
    }

    #[test]
    fn decode_rejects_malformed_packets() {
        let good = encode_packet(0, 1, &[ev(1, 1, 1)]);
        // Short, bad magic, bad version, truncated payload, trailing
        // junk, and an event-count over-claim.
        assert!(decode_packet(&good[..10]).unwrap_err().contains("short packet"));
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(decode_packet(&bad).unwrap_err().contains("magic"));
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(decode_packet(&bad).unwrap_err().contains("version"));
        assert!(decode_packet(&good[..good.len() - 1]).unwrap_err().contains("1 event(s)"));
        let mut bad = good.clone();
        bad.push(0);
        assert!(decode_packet(&bad).unwrap_err().contains("byte(s)"));
        let mut bad = good.clone();
        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_packet(&bad).unwrap_err().contains("cap"));
        // A v2 header truncated past the v1 length but short of the v2
        // length is caught before any field read.
        assert!(decode_packet(&good[..17]).unwrap_err().contains("short v2"));
    }

    #[test]
    fn dma_buffer_flushes_on_size_or_timeout() {
        let mut dma = DmaBuffer::new(2, Duration::from_millis(50));
        let t0 = Instant::now();
        let req = || {
            Ok(SourcedRequest {
                label: 0,
                events: vec![],
                arrival: Instant::now(),
                tenant: 0,
                model: 0,
                stream: None,
            })
        };
        assert!(dma.push(req(), t0).is_none(), "below the size threshold");
        assert!(dma.due(t0 + Duration::from_millis(10)).is_none(), "deadline not reached");
        let batch = dma.push(req(), t0).expect("size threshold flushes");
        assert_eq!(batch.len(), 2);
        assert!(dma.due(t0 + Duration::from_secs(1)).is_none(), "empty buffer never flushes");
        assert!(dma.push(req(), t0).is_none());
        let batch = dma.due(t0 + Duration::from_millis(50)).expect("deadline flushes");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn udp_source_receives_validates_and_tags_tenants() {
        let mut src = NetSource::udp(0, 8, 8, quick_cfg()).unwrap();
        let port = src.local_port();
        assert_eq!(src.geometry(), (8, 8));
        let out = UdpSocket::bind(("127.0.0.1", 0)).unwrap();
        let dst = ("127.0.0.1", port);
        out.send_to(&encode_packet(0, 3, &[ev(1, 1, 1)]), dst).unwrap();
        out.send_to(&encode_packet(1, 5, &[ev(2, 2, 2)]), dst).unwrap();
        // Out-of-geometry payload: recoverable, attributed to tenant 1.
        out.send_to(&encode_packet(1, 0, &[ev(3, 200, 0)]), dst).unwrap();
        // Unknown tenant: recoverable, unattributed.
        out.send_to(&encode_packet(9, 0, &[ev(4, 1, 1)]), dst).unwrap();
        // Garbage datagram: recoverable.
        out.send_to(b"not a packet at all", dst).unwrap();

        let a = src.next_request().unwrap().expect("first packet");
        assert_eq!((a.label, a.tenant), (3, 0));
        assert_eq!(a.stream, None, "datagrams carry no stream identity");
        let b = src.next_request().unwrap().expect("second packet");
        assert_eq!((b.label, b.tenant), (5, 1));
        let geom = src.next_request().unwrap_err();
        assert!(geom.is_recoverable(), "{geom}");
        assert!(geom.to_string().contains("geometry"), "{geom}");
        assert_eq!(geom.tenant(), Some(1));
        let unk = src.next_request().unwrap_err();
        assert!(unk.is_recoverable() && unk.to_string().contains("unknown tenant"), "{unk}");
        assert_eq!(unk.tenant(), None);
        let junk = src.next_request().unwrap_err();
        assert!(junk.is_recoverable(), "{junk}");
        // Nothing further: the idle timeout ends the stream.
        assert!(src.next_request().unwrap().is_none());
    }

    #[test]
    fn tcp_source_frames_streams_per_connection() {
        let mut src = NetSource::tcp(0, 8, 8, quick_cfg()).unwrap();
        let port = src.local_port();
        let frame = |pkt: &[u8]| {
            let mut f = (pkt.len() as u32).to_le_bytes().to_vec();
            f.extend_from_slice(pkt);
            f
        };
        let mut c0 = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut c1 = TcpStream::connect(("127.0.0.1", port)).unwrap();
        c0.write_all(&frame(&encode_packet(0, 1, &[ev(1, 1, 1)]))).unwrap();
        c1.write_all(&frame(&encode_packet(1, 2, &[ev(2, 2, 2)]))).unwrap();
        c0.write_all(&frame(&encode_packet(0, 3, &[ev(3, 3, 3)]))).unwrap();
        c0.flush().unwrap();
        c1.flush().unwrap();
        drop(c0);
        drop(c1);
        let mut got = Vec::new();
        while let Some(r) = src.next_request().unwrap() {
            let stream = r.stream.expect("tcp packets carry a stream identity");
            assert_eq!((stream >> 32) as usize, r.tenant, "tenant rides the high half");
            got.push((r.tenant, r.label, stream));
        }
        got.sort_unstable();
        let triples: Vec<_> = got.iter().map(|&(t, l, _)| (t, l)).collect();
        assert_eq!(triples, vec![(0, 1), (0, 3), (1, 2)]);
        // Same connection ⇒ same stream; different connections differ.
        assert_eq!(got[0].2, got[1].2, "c0's two packets share a stream");
        assert_ne!(got[0].2, got[2].2, "c0 and c1 are distinct streams");
    }

    #[test]
    fn tcp_bad_frame_drops_the_connection_recoverably() {
        let mut src = NetSource::tcp(0, 8, 8, quick_cfg()).unwrap();
        let port = src.local_port();
        let mut c = TcpStream::connect(("127.0.0.1", port)).unwrap();
        // An absurd frame length: the connection is dropped, the reject
        // surfaces recoverably, and the listener keeps serving.
        c.write_all(&u32::MAX.to_le_bytes()).unwrap();
        c.flush().unwrap();
        let err = src.next_request().unwrap_err();
        assert!(err.is_recoverable(), "{err}");
        assert!(err.to_string().contains("bad frame length"), "{err}");
        let mut c2 = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let pkt = encode_packet(0, 9, &[ev(1, 1, 1)]);
        c2.write_all(&(pkt.len() as u32).to_le_bytes()).unwrap();
        c2.write_all(&pkt).unwrap();
        c2.flush().unwrap();
        let r = src.next_request().unwrap().expect("listener survived the bad producer");
        assert_eq!(r.label, 9);
    }

    #[test]
    fn net_source_honors_limit() {
        let mut src = NetSource::udp(0, 8, 8, quick_cfg()).unwrap().with_limit(1);
        let port = src.local_port();
        let out = UdpSocket::bind(("127.0.0.1", 0)).unwrap();
        for _ in 0..3 {
            out.send_to(&encode_packet(0, 1, &[ev(1, 1, 1)]), ("127.0.0.1", port)).unwrap();
        }
        assert!(src.next_request().unwrap().is_some());
        assert!(src.next_request().unwrap().is_none(), "limit caps the stream");
    }
}
