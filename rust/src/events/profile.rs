//! Per-dataset profiles matching the paper's five evaluation datasets
//! (§4.1, Fig. 12, Table 1): spatial resolution, class count, clip window,
//! and generator parameters tuned so the **input nonzero ratio** lands in
//! the published range (N-Caltech101 ≈ 23.1% down to ASL-DVS ≈ 1.1%... the
//! per-dataset Fig. 12 input densities).

use super::synth::{class_scene, generate, Scene, SynthParams};
use crate::util::Rng;

/// Static description of one evaluation dataset.
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Feature-map width/height (paper Table 1 "Resolution", W×H).
    pub w: usize,
    pub h: usize,
    pub n_classes: usize,
    /// Clip interval for 2D representations (µs).
    pub window_us: u32,
    /// Target input NZ ratio (paper Fig. 12 input stage), for validation.
    pub target_input_nz: f64,
    /// Generator parameters.
    pub params: SynthParams,
    /// Object extent in px (scales with resolution).
    pub extent_px: f64,
}

impl DatasetProfile {
    /// The five paper datasets.
    pub fn all() -> Vec<DatasetProfile> {
        vec![
            Self::dvs_gesture(),
            Self::roshambo17(),
            Self::asl_dvs(),
            Self::n_mnist(),
            Self::n_caltech101(),
        ]
    }

    pub fn by_name(name: &str) -> Option<DatasetProfile> {
        Self::all().into_iter().find(|p| p.name.eq_ignore_ascii_case(name))
    }

    /// DvsGesture: 128×128, 10 gestures, moderately sparse (~6% input NZ).
    pub fn dvs_gesture() -> DatasetProfile {
        let (w, h) = (128, 128);
        DatasetProfile {
            name: "dvs_gesture",
            w,
            h,
            n_classes: 10,
            window_us: 50_000,
            target_input_nz: 0.064,
            params: SynthParams {
                w,
                h,
                duration_us: 50_000,
                step_us: 500,
                fire_p: 0.55,
                noise_per_step: 1.2,
                jitter_px: 6.0,
            },
            extent_px: 34.0,
        }
    }

    /// RoShamBo17: 64×64, 3 hand shapes (~12% input NZ).
    pub fn roshambo17() -> DatasetProfile {
        let (w, h) = (64, 64);
        DatasetProfile {
            name: "roshambo17",
            w,
            h,
            n_classes: 3,
            window_us: 40_000,
            target_input_nz: 0.12,
            params: SynthParams {
                w,
                h,
                duration_us: 40_000,
                step_us: 500,
                fire_p: 0.6,
                noise_per_step: 1.5,
                jitter_px: 4.0,
            },
            extent_px: 20.0,
        }
    }

    /// ASL-DVS: 240×180 (DAVIS240C), 24 letters, extremely sparse (~1.1%).
    pub fn asl_dvs() -> DatasetProfile {
        let (w, h) = (240, 180);
        DatasetProfile {
            name: "asl_dvs",
            w,
            h,
            n_classes: 24,
            window_us: 30_000,
            target_input_nz: 0.011,
            params: SynthParams {
                w,
                h,
                duration_us: 30_000,
                step_us: 400,
                fire_p: 0.6,
                noise_per_step: 2.5,
                jitter_px: 10.0,
            },
            extent_px: 30.0,
        }
    }

    /// N-MNIST: 34×34 saccade recaptures, 10 digits (~23% input NZ — small
    /// frames are relatively dense).
    pub fn n_mnist() -> DatasetProfile {
        let (w, h) = (34, 34);
        DatasetProfile {
            name: "n_mnist",
            w,
            h,
            n_classes: 10,
            window_us: 30_000,
            target_input_nz: 0.231,
            params: SynthParams {
                w,
                h,
                duration_us: 30_000,
                step_us: 400,
                fire_p: 0.7,
                noise_per_step: 1.0,
                jitter_px: 2.0,
            },
            extent_px: 11.0,
        }
    }

    /// N-Caltech101: 240×180 saccade recaptures, larger/denser objects
    /// (~10% input NZ; the densest large-resolution dataset in Fig. 12).
    /// The real set has 101 categories; the synthetic stand-in keeps the
    /// resolution/density profile with a reduced 10-way label space
    /// (documented substitution — see DESIGN.md §2).
    pub fn n_caltech101() -> DatasetProfile {
        let (w, h) = (240, 180);
        DatasetProfile {
            name: "n_caltech101",
            w,
            h,
            n_classes: 10,
            window_us: 30_000,
            target_input_nz: 0.10,
            params: SynthParams {
                w,
                h,
                duration_us: 30_000,
                step_us: 250,
                fire_p: 0.8,
                noise_per_step: 10.0,
                jitter_px: 12.0,
            },
            extent_px: 85.0,
        }
    }

    /// Scene for one class of this dataset.
    pub fn scene(&self, class: usize) -> Scene {
        class_scene(class, self.n_classes, self.extent_px)
    }

    /// Generate one labelled recording.
    pub fn sample(&self, class: usize, rng: &mut Rng) -> Vec<super::Event> {
        generate(&self.scene(class), &self.params, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::repr::histogram2;

    #[test]
    fn profiles_resolve_by_name() {
        for p in DatasetProfile::all() {
            assert_eq!(DatasetProfile::by_name(p.name).unwrap().name, p.name);
        }
        assert!(DatasetProfile::by_name("nope").is_none());
    }

    /// Input NZ ratios must land near the paper's Fig. 12 values — this is
    /// the knob everything else depends on.
    #[test]
    fn input_sparsity_matches_paper_targets() {
        let mut rng = Rng::new(1234);
        for p in DatasetProfile::all() {
            let mut ratios = Vec::new();
            for class in 0..p.n_classes.min(4) {
                for _ in 0..3 {
                    let es = p.sample(class, &mut rng);
                    let m = histogram2(&es, p.w, p.h);
                    ratios.push(m.nz_ratio());
                }
            }
            let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
            let lo = p.target_input_nz * 0.4;
            let hi = p.target_input_nz * 2.5;
            assert!(
                mean >= lo && mean <= hi,
                "{}: mean NZ {:.4} outside [{:.4}, {:.4}]",
                p.name,
                mean,
                lo,
                hi
            );
        }
    }
}
