//! Compile-once/execute-many engine: plan/oracle equivalence and the
//! zero-allocation steady state.
//!
//! - Property: `ExecPlan` execution is **bit-exact** with the allocating
//!   per-op oracle (`model::exec::forward_i8`) across random networks and
//!   random sparse inputs, with one `ExecCtx` arena reused throughout.
//! - Batching: `Backend::classify_batch` equals the sequential path.
//! - Allocation: after warm-up, plan execution performs zero heap
//!   allocations (counted by a thread-local counting global allocator).

use esda::coordinator::{Backend, Functional};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::model::exec::{classify_i8, forward_i8};
use esda::model::quant::{quantize_network, QuantizedNet};
use esda::model::weights::FloatWeights;
use esda::model::{Act, Block, DeltaCache, ExecCtx, ExecPlan, NetworkSpec};
use esda::sparse::{SparseMap, Token};
use esda::util::alloc::CountingAllocator;
use esda::util::propcheck::{check, Gen};
use esda::util::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn random_map(rng: &mut Rng, w: usize, h: usize, c: usize, p: f64) -> SparseMap<f32> {
    let mut m = SparseMap::empty(w, h, c);
    for y in 0..h {
        for x in 0..w {
            if rng.chance(p) {
                let f: Vec<f32> = (0..c).map(|_| (rng.f32() - 0.5) * 4.0).collect();
                m.push(Token::new(x as u16, y as u16), &f);
            }
        }
    }
    m
}

/// A random compact classification network: stem (stride 1 or 2), a few
/// MBConv blocks (random width/expansion/stride; equal widths at stride 1
/// produce residual fork/add pairs), an optional channel mixer, PoolFc.
fn random_spec(g: &mut Gen) -> NetworkSpec {
    let w = g.usize(8, 20);
    let h = g.usize(8, 20);
    let n_classes = g.usize(2, 5);
    let stem_cout = g.usize(2, 4);
    let mut blocks = vec![Block::Stem {
        k: 3,
        cout: stem_cout,
        stride: if g.chance(0.25) { 2 } else { 1 },
    }];
    let mut prev = stem_cout;
    for _ in 0..g.usize(1, 3) {
        let cout = if g.chance(0.4) { prev } else { g.usize(2, 6) };
        blocks.push(Block::MBConv {
            cout,
            expand: g.usize(1, 2),
            k: 3,
            stride: if g.chance(0.3) { 2 } else { 1 },
        });
        prev = cout;
    }
    if g.chance(0.5) {
        blocks.push(Block::Conv1x1 { cout: g.usize(2, 6), act: Act::Relu6 });
    }
    blocks.push(Block::PoolFc);
    NetworkSpec { name: "prop".into(), w, h, cin: 2, n_classes, blocks }
}

fn quantized(g: &mut Gen, spec: &NetworkSpec) -> QuantizedNet {
    let weights = FloatWeights::random(spec, g.u64(0..=u64::MAX - 1));
    let calib: Vec<SparseMap<f32>> = (0..2)
        .map(|_| random_map(g.rng(), spec.w, spec.h, spec.cin, 0.3))
        .collect();
    quantize_network(spec, &weights, &calib)
}

/// The tentpole property: plan execution is bit-exact with the oracle on
/// random networks and random inputs, including through arena reuse (one
/// context serves every case's inputs in sequence, and sparse/empty inputs
/// exercise the downsample/pool edge cases).
#[test]
fn plan_is_bit_exact_with_oracle_on_random_networks() {
    check("ExecPlan == forward_i8 (bit-exact)", 24, |g| {
        let spec = random_spec(g);
        let qnet = quantized(g, &spec);
        let plan = ExecPlan::compile(&qnet);
        // One arena serves all of this case's inputs — reuse is part of
        // the property (cross-case reuse is covered in model::plan tests).
        let mut ctx = ExecCtx::new();
        for i in 0..3 {
            let density = [0.0, 0.15, 0.45][i % 3];
            let input = random_map(g.rng(), spec.w, spec.h, spec.cin, density);
            let want = forward_i8(&qnet, &input);
            let got = plan.execute(&mut ctx, &input).to_vec();
            assert_eq!(got, want, "logits diverged (case {i}, density {density})");
            assert_eq!(
                plan.classify(&mut ctx, &input),
                classify_i8(&qnet, &input),
                "classification diverged (case {i})"
            );
        }
    });
}

/// Next window of a sliding stream: keep most of `prev` verbatim, drop or
/// rewrite a sprinkling of sites, and turn on a few empty ones — the
/// per-pixel walk preserves ravel order, which `SparseMap::push` requires.
fn mutate_window(
    rng: &mut Rng,
    prev: &SparseMap<f32>,
    p_drop: f64,
    p_change: f64,
    p_new: f64,
) -> SparseMap<f32> {
    let (w, h, c) = (prev.w, prev.h, prev.c);
    let mut next = SparseMap::empty(w, h, c);
    for y in 0..h {
        for x in 0..w {
            let t = Token::new(x as u16, y as u16);
            match prev.find(x as u16, y as u16) {
                Some(i) => {
                    if rng.chance(p_drop) {
                        continue;
                    }
                    if rng.chance(p_change) {
                        let f: Vec<f32> = (0..c).map(|_| (rng.f32() - 0.5) * 4.0).collect();
                        next.push(t, &f);
                    } else {
                        next.push(t, prev.feat(i));
                    }
                }
                None => {
                    if rng.chance(p_new) {
                        let f: Vec<f32> = (0..c).map(|_| (rng.f32() - 0.5) * 4.0).collect();
                        next.push(t, &f);
                    }
                }
            }
        }
    }
    next
}

/// The delta tentpole property: `execute_delta` is **bit-exact** with the
/// full path on random networks across sliding-window streams — whichever
/// side of the `max_frac` fallback boundary each window lands on. The
/// thresholds 0.0 (everything falls back except a zero-diff window), 0.35
/// (the serving default), and 1.0 (never falls back) pin both branches
/// and the boundary itself; a repeated window exercises the zero-dirty
/// edge, and a fresh cache per threshold exercises the cold-start fall
/// back.
#[test]
fn execute_delta_is_bit_exact_with_execute_on_random_networks() {
    check("execute_delta == execute (bit-exact)", 16, |g| {
        let spec = random_spec(g);
        let qnet = quantized(g, &spec);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        let mut windows = vec![random_map(g.rng(), spec.w, spec.h, spec.cin, 0.3)];
        for i in 0..5 {
            let prev = windows.last().unwrap();
            let next = if i == 2 {
                prev.clone() // zero-dirty repeat
            } else {
                let churn = [0.02, 0.3][i % 2]; // small and large diffs
                mutate_window(g.rng(), prev, churn, churn, churn / 4.0)
            };
            windows.push(next);
        }
        for max_frac in [0.0, 0.35, 1.0] {
            let mut cache = DeltaCache::new();
            let mut hits = 0usize;
            for (i, m) in windows.iter().enumerate() {
                let want = plan.execute(&mut ctx, m).to_vec();
                let (got, outcome) = plan.execute_delta(&mut ctx, &mut cache, m, max_frac);
                assert_eq!(
                    got, want,
                    "logits diverged (window {i}, max_frac {max_frac}, {outcome:?})"
                );
                hits += outcome.is_delta() as usize;
            }
            if max_frac >= 1.0 {
                assert_eq!(hits, windows.len() - 1, "only the cold start may fall back");
            }
        }
    });
}

/// The delta acceptance bar: once the per-stream cache is warm, both the
/// dirty-frontier path (`max_frac` 1.0) and the over-threshold fallback
/// (`max_frac` 0.0, which re-stores every layer into the cache) make
/// **zero** heap allocations per window.
#[test]
fn delta_steady_state_is_allocation_free() {
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::compact("compact", profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 11);
    let mut rng = Rng::new(33);
    let base = {
        let es = profile.sample(0, &mut rng);
        histogram2_norm(&es, profile.w, profile.h, 8.0)
    };
    let qnet = quantize_network(&spec, &weights, std::slice::from_ref(&base));
    let plan = ExecPlan::compile(&qnet);
    let mut windows = vec![base];
    for _ in 0..5 {
        let next = mutate_window(&mut rng, windows.last().unwrap(), 0.05, 0.05, 0.01);
        windows.push(next);
    }
    let mut preds = 0usize;
    for max_frac in [1.0, 0.0] {
        let mut ctx = ExecCtx::new();
        let mut cache = DeltaCache::new();
        // Two warm passes size every arena buffer (the measured pass
        // replays the same windows, so no buffer can need to grow).
        for _ in 0..2 {
            for m in &windows {
                preds += plan.classify_delta(&mut ctx, &mut cache, m, max_frac).0;
            }
        }
        let before = CountingAllocator::thread_allocs();
        for _ in 0..4 {
            for m in &windows {
                preds += plan.classify_delta(&mut ctx, &mut cache, m, max_frac).0;
            }
        }
        let after = CountingAllocator::thread_allocs();
        assert_eq!(
            after - before,
            0,
            "steady-state delta execution touched the heap (max_frac {max_frac}, {} allocs)",
            after - before
        );
    }
    assert!(preds < 16 * windows.len() * profile.n_classes);
}

/// Batched and sequential classification agree through the `Backend`
/// trait, for every batch size.
#[test]
fn classify_batch_prediction_equality() {
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 3);
    let mut rng = Rng::new(9);
    let mk = |rng: &mut Rng, i: usize| {
        let es = profile.sample(i % profile.n_classes, rng);
        histogram2_norm(&es, profile.w, profile.h, 8.0)
    };
    let calib: Vec<SparseMap<f32>> = (0..3).map(|i| mk(&mut rng, i)).collect();
    let backend = Functional::new(quantize_network(&spec, &weights, &calib));
    let maps: Vec<SparseMap<f32>> = (0..12).map(|i| mk(&mut rng, i)).collect();
    let seq: Vec<usize> = maps.iter().map(|m| backend.classify(m).unwrap().pred).collect();
    for chunk in [1usize, 4, 16] {
        let mut batched = Vec::new();
        for maps in maps.chunks(chunk) {
            for r in backend.classify_batch(maps) {
                batched.push(r.unwrap().pred);
            }
        }
        assert_eq!(batched, seq, "batch size {chunk} changed predictions");
    }
}

/// The acceptance bar for the arena: once warmed, executing the plan makes
/// **zero** heap allocations per inference.
#[test]
fn steady_state_execution_is_allocation_free() {
    let profile = DatasetProfile::n_mnist();
    let spec = NetworkSpec::compact("compact", profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 11);
    let mut rng = Rng::new(21);
    let inputs: Vec<SparseMap<f32>> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &inputs);
    let plan = ExecPlan::compile(&qnet);
    let mut ctx = ExecCtx::new();
    // Warm-up pass sizes every arena buffer.
    for m in &inputs {
        plan.classify(&mut ctx, m);
    }
    let before = CountingAllocator::thread_allocs();
    let mut preds = 0usize;
    for _ in 0..8 {
        for m in &inputs {
            preds += plan.classify(&mut ctx, m);
        }
    }
    let after = CountingAllocator::thread_allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state arena execution touched the heap ({} allocs over {} inferences)",
        after - before,
        8 * inputs.len()
    );
    // Keep the classification results observable so the loop cannot be
    // optimized away.
    assert!(preds < 8 * inputs.len() * profile.n_classes);
}
