//! The Eqn. 6 resource-allocation solver.
//!
//! ```text
//! min  lat           s.t.  lat_i ≤ lat   ∀ layers i
//!                          Σ_i r_ij ≤ R_j  for j ∈ {DSP, BRAM}
//! ```
//!
//! Latency is monotone non-increasing and resources monotone non-decreasing
//! in each layer's PF, so the optimum has a clean structure: for a target
//! bottleneck `T`, each layer independently needs its *minimum* PF with
//! `lat_i(PF) ≤ T`; feasibility is then a simple budget check. The optimal
//! `T` is found by binary search over the finite set of achievable layer
//! latencies (exact — no continuous tolerance). An exhaustive reference
//! solver cross-checks small instances in tests.

use super::cost::{op_cost, total_resources, OpCost, Resources};
use super::stats::LayerStats;
use crate::model::graph::NetworkSpec;

/// Resource budget (defaults: ZCU102 / XCZU9EG as in the paper).
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub dsp: usize,
    pub bram: usize,
}

impl Budget {
    /// ZCU102: 2520 DSP48, 1824 BRAM18 (912 BRAM36).
    pub fn zcu102() -> Budget {
        Budget { dsp: 2520, bram: 1824 }
    }
}

/// Candidate parallel factors (powers of two — the weight-partitioning
/// granularity of the paper's templates).
pub const PF_CHOICES: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Allocation outcome.
#[derive(Debug, Clone)]
pub struct AllocResult {
    /// PF per op (1 for weightless ops).
    pub pf: Vec<usize>,
    /// Bottleneck latency (cycles/inference) under the Eqn. 5 model.
    pub latency: f64,
    pub costs: Vec<OpCost>,
    pub resources: Resources,
}

/// Minimal PF (from `PF_CHOICES`) achieving `lat ≤ target`; None if even
/// the largest PF misses the target.
fn min_pf_for(
    op: &crate::model::graph::Op,
    st: &LayerStats,
    target: f64,
    w: usize,
    h: usize,
) -> Option<usize> {
    for &pf in PF_CHOICES {
        if op_cost(op, st, pf, w, h).latency <= target {
            return Some(pf);
        }
    }
    None
}

/// Try target `t`: per-layer minimal PFs + budget check.
fn try_target(
    spec: &NetworkSpec,
    stats: &[LayerStats],
    budget: &Budget,
    t: f64,
) -> Option<AllocResult> {
    let ops = spec.ops();
    let res = spec.op_resolutions();
    let mut pfs = Vec::with_capacity(ops.len());
    for (i, op) in ops.iter().enumerate() {
        let pf = min_pf_for(op, &stats[i], t, res[i].0, res[i].1)?;
        pfs.push(pf);
    }
    let costs: Vec<OpCost> = ops
        .iter()
        .enumerate()
        .map(|(i, op)| op_cost(op, &stats[i], pfs[i], res[i].0, res[i].1))
        .collect();
    let total = total_resources(&costs);
    if total.dsp > budget.dsp || total.bram > budget.bram {
        return None;
    }
    let latency = costs.iter().map(|c| c.latency).fold(0.0, f64::max);
    Some(AllocResult { pf: pfs, latency, costs, resources: total })
}

/// Solve Eqn. 6: returns None when even PF=max everywhere cannot fit the
/// budget (model too large for the device).
pub fn allocate(spec: &NetworkSpec, stats: &[LayerStats], budget: &Budget) -> Option<AllocResult> {
    let ops = spec.ops();
    let res = spec.op_resolutions();
    assert_eq!(ops.len(), stats.len());
    // Candidate bottleneck values: every achievable per-layer latency.
    let mut candidates: Vec<f64> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        for &pf in PF_CHOICES {
            candidates.push(op_cost(op, &stats[i], pf, res[i].0, res[i].1).latency);
        }
    }
    candidates.retain(|l| l.is_finite());
    candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    candidates.dedup();
    // Binary search the smallest feasible candidate target.
    let mut lo = 0usize;
    let mut hi = candidates.len();
    let mut best: Option<AllocResult> = None;
    while lo < hi {
        let mid = (lo + hi) / 2;
        match try_target(spec, stats, budget, candidates[mid]) {
            Some(r) => {
                best = Some(r);
                hi = mid;
            }
            None => lo = mid + 1,
        }
    }
    best
}

/// Exhaustive reference solver for tests: enumerate all PF combinations of
/// the *weighted* ops (weightless ops fixed at PF=1). Exponential — only
/// for tiny programs.
pub fn allocate_exhaustive(
    spec: &NetworkSpec,
    stats: &[LayerStats],
    budget: &Budget,
    pf_choices: &[usize],
) -> Option<AllocResult> {
    let ops = spec.ops();
    let res = spec.op_resolutions();
    let weighted: Vec<usize> = (0..ops.len()).filter(|&i| ops[i].has_weights()).collect();
    assert!(weighted.len() <= 8, "exhaustive solver is for tiny programs");
    let mut best: Option<AllocResult> = None;
    let n_comb = pf_choices.len().pow(weighted.len() as u32);
    for comb in 0..n_comb {
        let mut pfs = vec![1usize; ops.len()];
        let mut c = comb;
        for &wi in &weighted {
            pfs[wi] = pf_choices[c % pf_choices.len()];
            c /= pf_choices.len();
        }
        let costs: Vec<OpCost> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| op_cost(op, &stats[i], pfs[i], res[i].0, res[i].1))
            .collect();
        let total = total_resources(&costs);
        if total.dsp > budget.dsp || total.bram > budget.bram {
            continue;
        }
        let latency = costs.iter().map(|k| k.latency).fold(0.0, f64::max);
        if best.as_ref().map_or(true, |b| latency < b.latency) {
            best = Some(AllocResult { pf: pfs, latency, costs, resources: total });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwopt::stats::collect_stats;
    use crate::model::NetworkSpec;
    use crate::sparse::Bitmap;
    use crate::util::propcheck::check;
    use crate::util::Rng;

    fn tiny_setup(seed: u64, p: f64) -> (NetworkSpec, Vec<LayerStats>) {
        let spec = NetworkSpec::tiny(16, 16, 4);
        let mut rng = Rng::new(seed);
        let mut bms = Vec::new();
        for _ in 0..3 {
            let mut b = Bitmap::new(16, 16);
            for y in 0..16 {
                for x in 0..16 {
                    if rng.chance(p) {
                        b.set(x, y);
                    }
                }
            }
            bms.push(b);
        }
        let stats = collect_stats(&spec, &bms);
        (spec, stats)
    }

    #[test]
    fn allocation_respects_budget_and_improves_with_budget() {
        let (spec, stats) = tiny_setup(1, 0.25);
        let small = Budget { dsp: 16, bram: 64 };
        let large = Budget { dsp: 512, bram: 1024 };
        let rs = allocate(&spec, &stats, &small).expect("small-budget allocation");
        let rl = allocate(&spec, &stats, &large).expect("large-budget allocation");
        assert!(rs.resources.dsp <= small.dsp && rs.resources.bram <= small.bram);
        assert!(rl.resources.dsp <= large.dsp && rl.resources.bram <= large.bram);
        assert!(rl.latency <= rs.latency);
    }

    #[test]
    fn matches_exhaustive_reference_bottleneck() {
        check("Eqn6 solver == exhaustive min-bottleneck", 24, |g| {
            let (spec, stats) = tiny_setup(g.u64(0..=1 << 30), 0.1 + g.f64() * 0.4);
            let budget = Budget { dsp: g.usize(8, 64), bram: g.usize(32, 256) };
            let choices: &[usize] = &[1, 4, 16];
            // Restrict the fast solver to the same PF choices via a local
            // exhaustive reference on weighted ops.
            let want = allocate_exhaustive(&spec, &stats, &budget, choices);
            // The production solver searches the full PF set; emulate the
            // restricted set by calling the reference twice — instead check
            // the production solver achieves ≤ the reference bottleneck
            // under the full choice set (superset ⇒ at least as good).
            let got = allocate(&spec, &stats, &budget);
            match (got, want) {
                (Some(g_), Some(w)) => {
                    assert!(
                        g_.latency <= w.latency + 1e-9,
                        "solver {} worse than exhaustive {}",
                        g_.latency,
                        w.latency
                    );
                }
                (Some(_), None) => {} // full PF set found something the
                                       // restricted set couldn't — fine
                (None, Some(w)) => panic!("solver failed where exhaustive found {}", w.latency),
                (None, None) => {}
            }
        });
    }

    #[test]
    fn infeasible_when_budget_tiny() {
        let (spec, stats) = tiny_setup(5, 0.3);
        // One BRAM cannot hold the weights of every layer.
        assert!(allocate(&spec, &stats, &Budget { dsp: 1, bram: 1 }).is_none());
    }

    #[test]
    fn weightless_ops_get_pf1() {
        let (spec, stats) = tiny_setup(7, 0.2);
        let r = allocate(&spec, &stats, &Budget::zcu102()).unwrap();
        let ops = spec.ops();
        for (i, op) in ops.iter().enumerate() {
            if !op.has_weights() {
                assert_eq!(r.pf[i], 1, "op {i} {:?}", op);
            }
        }
    }

    #[test]
    fn sparser_data_lower_latency() {
        let (spec, s_sparse) = tiny_setup(9, 0.05);
        let (_, s_dense) = tiny_setup(9, 0.5);
        let b = Budget { dsp: 64, bram: 128 };
        let rs = allocate(&spec, &s_sparse, &b).unwrap();
        let rd = allocate(&spec, &s_dense, &b).unwrap();
        assert!(rs.latency < rd.latency);
    }
}
