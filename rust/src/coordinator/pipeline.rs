//! The threaded serving pipeline.
//!
//! Three stages on std threads with bounded channels (backpressure):
//! 1. **source** — draws labelled event recordings (synthetic camera),
//! 2. **repr** — clips windows and builds the 2-channel histogram (the
//!    "processing system" work of Fig. 2),
//! 3. **accel** — classifies via the selected backend: the cycle-level
//!    hardware simulator (batch-1, the paper's deployment) or the PJRT
//!    dense engine (the GPU-platform stand-in).

use super::metrics::{Metrics, RequestTiming};
use crate::arch::{simulate_inference, HwConfig};
use crate::events::{repr::histogram2_norm, DatasetProfile};
use crate::model::exec::{argmax, forward_i8};
use crate::model::quant::QuantizedNet;
use crate::sparse::SparseMap;
use crate::util::Rng;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

/// Classification backend.
pub enum Backend {
    /// Cycle-level ESDA simulator (reports hardware cycles too).
    Simulator { qnet: QuantizedNet, cfg: HwConfig },
    /// Functional int8 reference (fast; no cycle model).
    Functional { qnet: QuantizedNet },
    /// PJRT dense engine (AOT artifact).
    Dense { engine: crate::runtime::Engine },
}

/// Pipeline configuration.
pub struct PipelineConfig {
    pub n_requests: usize,
    pub seed: u64,
    /// Channel depth between stages.
    pub queue_depth: usize,
    /// Histogram clip value.
    pub clip: f32,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { n_requests: 32, seed: 1, queue_depth: 4, clip: 8.0 }
    }
}

/// Outcome of a pipeline run.
pub struct PipelineResult {
    pub metrics: Metrics,
}

struct Request {
    label: usize,
    map: SparseMap<f32>,
    enqueued: Instant,
}

/// Run the three-stage pipeline to completion.
pub fn run_pipeline(
    profile: &DatasetProfile,
    backend: &Backend,
    cfg: &PipelineConfig,
) -> PipelineResult {
    let (tx_ev, rx_ev): (SyncSender<(usize, Vec<crate::events::Event>)>, Receiver<_>) =
        sync_channel(cfg.queue_depth);
    let (tx_req, rx_req): (SyncSender<Request>, Receiver<Request>) = sync_channel(cfg.queue_depth);

    // Stage 1: synthetic event camera.
    let p1 = profile.clone();
    let n = cfg.n_requests;
    let seed = cfg.seed;
    let source = std::thread::spawn(move || {
        let mut rng = Rng::new(seed);
        for i in 0..n {
            let class = i % p1.n_classes;
            let events = p1.sample(class, &mut rng);
            if tx_ev.send((class, events)).is_err() {
                return;
            }
        }
    });

    // Stage 2: representation builder.
    let (w, h) = (profile.w, profile.h);
    let clip = cfg.clip;
    let repr = std::thread::spawn(move || {
        for (label, events) in rx_ev.iter() {
            let map = histogram2_norm(&events, w, h, clip);
            let req = Request { label, map, enqueued: Instant::now() };
            if tx_req.send(req).is_err() {
                return;
            }
        }
    });

    // Stage 3: accelerator (runs on the caller thread).
    let mut metrics = Metrics::default();
    for req in rx_req.iter() {
        let t0 = Instant::now();
        let (pred, sim_cycles) = classify(backend, &req.map);
        let service_s = t0.elapsed().as_secs_f64();
        let e2e_s = req.enqueued.elapsed().as_secs_f64();
        metrics.record(
            RequestTiming { e2e_s, service_s, sim_cycles },
            pred == req.label,
        );
    }
    source.join().expect("source thread");
    repr.join().expect("repr thread");
    PipelineResult { metrics }
}

fn classify(backend: &Backend, map: &SparseMap<f32>) -> (usize, Option<u64>) {
    match backend {
        Backend::Simulator { qnet, cfg } => {
            let (logits, report) =
                simulate_inference(qnet, cfg, map, 10_000_000_000).expect("simulation");
            (argmax(&logits), Some(report.cycles))
        }
        Backend::Functional { qnet } => (argmax(&forward_i8(qnet, map)), None),
        Backend::Dense { engine } => {
            let logits = engine.infer_sparse(map).expect("dense inference");
            (argmax(&logits), None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quant::quantize_network;
    use crate::model::weights::FloatWeights;
    use crate::model::NetworkSpec;

    fn qnet_for(profile: &DatasetProfile) -> QuantizedNet {
        let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
        let w = FloatWeights::random(&spec, 3);
        let mut rng = Rng::new(9);
        let calib: Vec<SparseMap<f32>> = (0..2)
            .map(|i| {
                let es = profile.sample(i, &mut rng);
                histogram2_norm(&es, profile.w, profile.h, 8.0)
            })
            .collect();
        quantize_network(&spec, &w, &calib)
    }

    #[test]
    fn functional_backend_processes_all_requests() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let backend = Backend::Functional { qnet };
        let cfg = PipelineConfig { n_requests: 12, seed: 4, queue_depth: 2, clip: 8.0 };
        let r = run_pipeline(&profile, &backend, &cfg);
        assert_eq!(r.metrics.total, 12);
        assert!(r.metrics.e2e_summary().mean() > 0.0);
        assert!(r.metrics.throughput() > 0.0);
    }

    #[test]
    fn simulator_backend_reports_cycles() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let n_ops = qnet.spec.ops().len();
        let backend = Backend::Simulator { qnet, cfg: HwConfig::uniform(n_ops, 16) };
        let cfg = PipelineConfig { n_requests: 3, seed: 5, queue_depth: 2, clip: 8.0 };
        let r = run_pipeline(&profile, &backend, &cfg);
        assert_eq!(r.metrics.total, 3);
        let lat = r.metrics.mean_sim_latency_ms(crate::hwopt::power::CLOCK_HZ).unwrap();
        assert!(lat > 0.0);
    }

    /// Simulator and functional backends must classify identically.
    #[test]
    fn backends_agree_on_predictions() {
        let profile = DatasetProfile::n_mnist();
        let qnet = qnet_for(&profile);
        let mut rng = Rng::new(77);
        for i in 0..3 {
            let es = profile.sample(i, &mut rng);
            let map = histogram2_norm(&es, profile.w, profile.h, 8.0);
            let n_ops = qnet.spec.ops().len();
            let (f, _) = classify(&Backend::Functional { qnet: qnet.clone() }, &map);
            let (s, _) = classify(
                &Backend::Simulator { qnet: qnet.clone(), cfg: HwConfig::uniform(n_ops, 8) },
                &map,
            );
            assert_eq!(f, s);
        }
    }
}
