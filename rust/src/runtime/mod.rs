//! PJRT runtime: load the AOT-compiled JAX model (HLO text emitted by
//! `python/compile/aot.py`) and execute it from Rust — the dense-inference
//! engine that (a) validates the L2/L1 artifacts against the rust oracle
//! and (b) serves as the "GPU dense" platform stand-in in Fig. 14.
//!
//! Python never runs on this path: the HLO text is compiled once by the
//! PJRT CPU client at load time and executed with concrete buffers
//! thereafter (see /opt/xla-example/load_hlo for the pattern, and
//! DESIGN.md for why HLO *text* is the interchange format).
//!
//! The `xla` crate closure is only available in environments that vendor
//! it, so the real engine is gated behind the default-off `pjrt` cargo
//! feature. Without it, [`Engine`] is a stub with the same API whose
//! `load` returns a descriptive error — callers (the `serve`/`infer` CLI
//! commands, the golden tests, the Fig. 14 bench) degrade gracefully and
//! the crate builds fully offline.

use std::fmt;

/// Runtime error (anyhow is not vendored in the offline default build).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime module.
pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

#[cfg(feature = "pjrt")]
mod engine_impl {
    use super::{err, Result};
    use std::path::Path;

    /// A loaded, compiled model artifact.
    pub struct Engine {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        /// Input geometry of the dense representation (h, w, c).
        pub h: usize,
        pub w: usize,
        pub c: usize,
        pub n_classes: usize,
    }

    // SAFETY OBLIGATION (on whoever vendors `xla` and enables `pjrt`,
    // since no in-tree build configuration compiles this module): this
    // asserts that moving the client/executable wrappers between threads
    // is sound, i.e. the vendored xla crate's handles carry no thread
    // affinity (Rc, thread-locals, unsynchronized C++ state). Verify
    // against the vendored crate before enabling; remove this impl and
    // construct one Engine per thread if it does not hold. We deliberately
    // do NOT assert `Sync`: concurrent callers must serialize access
    // themselves (`coordinator::Dense` wraps the engine in a mutex).
    unsafe impl Send for Engine {}

    impl Engine {
        /// Load an HLO-text artifact plus its metadata JSON
        /// (`<stem>.meta.json` next to it).
        pub fn load(hlo_path: &Path) -> Result<Engine> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| err(format!("PJRT client: {e:?}")))?;
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().ok_or_else(|| err("non-utf8 path"))?,
            )
            .map_err(|e| err(format!("parse HLO {hlo_path:?}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| err(format!("compile: {e:?}")))?;
            // Metadata: <stem>.meta.json next to <stem>.hlo.txt.
            let stem = hlo_path
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(|n| n.strip_suffix(".hlo.txt"))
                .ok_or_else(|| err(format!("artifact path must end in .hlo.txt: {hlo_path:?}")))?;
            let meta_path = hlo_path.with_file_name(format!("{stem}.meta.json"));
            let meta_src = std::fs::read_to_string(&meta_path)
                .map_err(|e| err(format!("read {meta_path:?}: {e}")))?;
            let meta =
                crate::util::json::parse(&meta_src).map_err(|e| err(format!("meta json: {e}")))?;
            let get = |k: &str| -> Result<usize> {
                meta.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| err(format!("meta missing '{k}'")))
            };
            Ok(Engine {
                client,
                exe,
                h: get("h")?,
                w: get("w")?,
                c: get("c")?,
                n_classes: get("n_classes")?,
            })
        }

        /// Run one dense inference: input is a dense `h × w × c` f32 buffer
        /// (channel-minor); returns the logits.
        pub fn infer_dense(&self, dense: &[f32]) -> Result<Vec<f32>> {
            if dense.len() != self.h * self.w * self.c {
                return Err(err("bad input size"));
            }
            let input = xla::Literal::vec1(dense)
                .reshape(&[self.h as i64, self.w as i64, self.c as i64])
                .map_err(|e| err(format!("reshape: {e:?}")))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[input])
                .map_err(|e| err(format!("execute: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("sync: {e:?}")))?;
            // aot.py lowers with return_tuple=True ⇒ 1-tuple.
            let out = result.to_tuple1().map_err(|e| err(format!("tuple: {e:?}")))?;
            let logits = out.to_vec::<f32>().map_err(|e| err(format!("to_vec: {e:?}")))?;
            if logits.len() != self.n_classes {
                return Err(err("logit arity"));
            }
            Ok(logits)
        }

        /// Run one inference on a sparse map (densifies at the boundary —
        /// this engine is the *dense* platform model).
        pub fn infer_sparse(&self, m: &crate::sparse::SparseMap<f32>) -> Result<Vec<f32>> {
            self.infer_dense(&m.to_dense())
        }

        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine_impl {
    use super::{err, Result};
    use std::path::Path;

    /// Stub engine: same API as the PJRT engine, available without the
    /// `pjrt` feature so the crate (and everything that names `Engine` in
    /// a type position) builds offline. `load` always fails, so no stub
    /// instance can ever reach `infer_*` through the public API.
    pub struct Engine {
        /// Input geometry of the dense representation (h, w, c).
        pub h: usize,
        pub w: usize,
        pub c: usize,
        pub n_classes: usize,
    }

    impl Engine {
        pub fn load(hlo_path: &Path) -> Result<Engine> {
            Err(err(format!(
                "cannot load {hlo_path:?}: built without the `pjrt` feature \
                 (enable it and add the vendored `xla` dependency in rust/Cargo.toml)"
            )))
        }

        pub fn infer_dense(&self, _dense: &[f32]) -> Result<Vec<f32>> {
            Err(err("PJRT engine unavailable: built without the `pjrt` feature"))
        }

        pub fn infer_sparse(&self, _m: &crate::sparse::SparseMap<f32>) -> Result<Vec<f32>> {
            Err(err("PJRT engine unavailable: built without the `pjrt` feature"))
        }

        pub fn device_count(&self) -> usize {
            0
        }
    }
}

pub use engine_impl::Engine;

/// True when this build carries the real PJRT engine. Artifact-gated
/// callers (golden tests, Fig. 14 bench, the e2e example) must check this
/// *in addition to* [`artifact_available`]: artifacts may exist on disk
/// while the stub engine cannot load them.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// Default artifact directory (next to the workspace root).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("ESDA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when the AOT artifacts for `stem` exist (tests skip gracefully
/// otherwise, so `cargo test` passes before `make artifacts`).
pub fn artifact_available(stem: &str) -> bool {
    artifacts_dir().join(format!("{stem}.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke: client construction works when the real engine is built.
    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_cpu_client_constructs() {
        let c = xla::PjRtClient::cpu().expect("PJRT CPU client");
        assert!(c.device_count() >= 1);
    }

    /// Without the feature, loading fails loudly instead of linking xla.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_load_reports_missing_feature() {
        let e = Engine::load(std::path::Path::new("artifacts/x.hlo.txt")).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "unhelpful error: {e}");
    }

    /// Full artifact round-trip — only once `make artifacts` has run.
    #[cfg(feature = "pjrt")]
    #[test]
    fn engine_loads_and_infers_if_artifacts_present() {
        let stem = "tiny_nmnist";
        if !artifact_available(stem) {
            eprintln!("skipping: artifacts/{stem}.hlo.txt not built yet");
            return;
        }
        let eng = Engine::load(&artifacts_dir().join(format!("{stem}.hlo.txt"))).unwrap();
        let dense = vec![0f32; eng.h * eng.w * eng.c];
        let logits = eng.infer_dense(&dense).unwrap();
        assert_eq!(logits.len(), eng.n_classes);
    }

    #[test]
    fn artifacts_dir_respects_env() {
        // Don't mutate the env (tests run in parallel); just check default.
        if std::env::var("ESDA_ARTIFACTS").is_err() {
            assert_eq!(artifacts_dir(), std::path::PathBuf::from("artifacts"));
        }
    }
}
