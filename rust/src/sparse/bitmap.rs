//! 2D occupancy bitmap over an H×W grid, plus the *pattern* transforms the
//! paper's Fig. 3 / Fig. 12 analysis needs:
//!
//! - [`Bitmap::dilate`] — nonzero pattern after a **standard** k×k conv
//!   (every output the kernel can reach becomes nonzero: the dilation
//!   effect).
//! - [`Bitmap::submanifold`] — pattern after a submanifold stride-1 conv
//!   (identical, by construction).
//! - [`Bitmap::downsample_sparse`] — pattern after a sparse stride-s conv
//!   (output set iff the s×s input grid contains any nonzero).
//! - [`Bitmap::downsample_standard`] — pattern after a standard stride-s
//!   k×k conv (output set iff the k×k window contains any nonzero).

/// Dense bitset over an `h × w` grid, row-major, 64 cells per word.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitmap {
    pub h: usize,
    pub w: usize,
    words: Vec<u64>,
}

impl Bitmap {
    pub fn new(w: usize, h: usize) -> Self {
        Bitmap {
            h,
            w,
            words: vec![0; (h * w).div_ceil(64)],
        }
    }

    /// Reset to an all-clear `w × h` grid, reusing the word storage — the
    /// arena-execution path (`model::plan`) calls this once per layer, so
    /// at steady state it must not touch the heap.
    pub fn reset(&mut self, w: usize, h: usize) {
        self.w = w;
        self.h = h;
        let need = (h * w).div_ceil(64);
        self.words.clear();
        self.words.resize(need, 0);
    }

    #[inline]
    fn idx(&self, x: usize, y: usize) -> (usize, u64) {
        let bit = y * self.w + x;
        (bit >> 6, 1u64 << (bit & 63))
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> bool {
        debug_assert!(x < self.w && y < self.h);
        let (wd, mask) = self.idx(x, y);
        self.words[wd] & mask != 0
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize) {
        debug_assert!(x < self.w && y < self.h);
        let (wd, mask) = self.idx(x, y);
        self.words[wd] |= mask;
    }

    #[inline]
    pub fn clear(&mut self, x: usize, y: usize) {
        let (wd, mask) = self.idx(x, y);
        self.words[wd] &= !mask;
    }

    /// Number of set cells.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction of set cells (the paper's NZ ratio / spatial sparsity S_s).
    pub fn nz_ratio(&self) -> f64 {
        self.count() as f64 / (self.h * self.w) as f64
    }

    /// Iterate set coordinates in ravel order.
    pub fn iter_set(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.h)
            .flat_map(move |y| (0..self.w).filter_map(move |x| self.get(x, y).then_some((x, y))))
    }

    /// Copy `src` into `self`, reusing the word storage (unlike
    /// `Clone::clone`) — the delta-execution path snapshots frontiers once
    /// per layer, so at steady state this must not touch the heap.
    pub fn copy_from(&mut self, src: &Bitmap) {
        self.w = src.w;
        self.h = src.h;
        self.words.clear();
        self.words.extend_from_slice(&src.words);
    }

    /// Pattern after a standard k×k stride-1 conv with `pad = (k-1)/2`:
    /// every output whose window touches a nonzero becomes nonzero.
    pub fn dilate(&self, k: usize) -> Bitmap {
        let mut out = Bitmap::new(self.w, self.h);
        self.dilate_into(k, &mut out);
        out
    }

    /// Arena variant of [`Bitmap::dilate`]: writes into `out`, reusing its
    /// storage. This is how the delta-execution path propagates a dirty-site
    /// frontier through a stride-1 k×k receptive field without allocating.
    pub fn dilate_into(&self, k: usize, out: &mut Bitmap) {
        assert!(k % 2 == 1, "odd kernels only");
        let u = (k - 1) / 2;
        out.reset(self.w, self.h);
        for (x, y) in self.iter_set() {
            let y0 = y.saturating_sub(u);
            let y1 = (y + u).min(self.h - 1);
            let x0 = x.saturating_sub(u);
            let x1 = (x + u).min(self.w - 1);
            for oy in y0..=y1 {
                for ox in x0..=x1 {
                    out.set(ox, oy);
                }
            }
        }
    }

    /// Propagate a *dirty-site* set through a stride-2 k×k sparse conv
    /// (pad `(k-1)/2`): an output is marked iff its k×k input window
    /// contains a marked input (its accumulated value may change), **or**
    /// it is the 2×2 grid cell of a marked input (its very existence in
    /// the output token set may change — the Fig. 3b occupancy rule).
    /// Equivalently: `downsample_standard(k, 2) ∪ downsample_sparse(2)`.
    /// Output geometry is `ceil(w/2) × ceil(h/2)`; `out` storage is reused.
    pub fn downsample_dirty_into(&self, k: usize, out: &mut Bitmap) {
        assert!(k % 2 == 1, "odd kernels only");
        let pad = (k - 1) / 2;
        let ow = (self.w + 1) / 2;
        let oh = (self.h + 1) / 2;
        out.reset(ow, oh);
        for (x, y) in self.iter_set() {
            // Window rule: x is read by outputs ox with
            // ox*2 ∈ [x+pad-k+1, x+pad]  ⇔  ox ∈ [⌈(x+pad-k+1)/2⌉, ⌊(x+pad)/2⌋].
            let x0 = (x + pad + 1).saturating_sub(k).div_ceil(2);
            let x1 = ((x + pad) / 2).min(ow - 1);
            let y0 = (y + pad + 1).saturating_sub(k).div_ceil(2);
            let y1 = ((y + pad) / 2).min(oh - 1);
            // The interval can be empty (e.g. k=1 at odd x): the window
            // rule then contributes nothing and only the occupancy rule
            // below applies.
            if x0 <= x1 && y0 <= y1 {
                for oy in y0..=y1 {
                    for ox in x0..=x1 {
                        out.set(ox, oy);
                    }
                }
            }
            // Occupancy rule: the grid cell this input feeds.
            out.set(x / 2, y / 2);
        }
    }

    /// Pattern after a submanifold stride-1 conv: unchanged.
    pub fn submanifold(&self) -> Bitmap {
        self.clone()
    }

    /// Pattern after a sparse (submanifold-style) stride-`s` conv:
    /// output `(ox, oy)` is nonzero iff any input in the `s×s` grid
    /// `(ox*s .. ox*s+s, oy*s .. oy*s+s)` is nonzero. Output is
    /// `ceil(w/s) × ceil(h/s)`.
    pub fn downsample_sparse(&self, s: usize) -> Bitmap {
        let ow = (self.w + s - 1) / s;
        let oh = (self.h + s - 1) / s;
        let mut out = Bitmap::new(ow, oh);
        for (x, y) in self.iter_set() {
            out.set(x / s, y / s);
        }
        out
    }

    /// Pattern after a standard k×k stride-`s` conv with `pad = (k-1)/2`:
    /// output nonzero iff its k×k input window contains any nonzero.
    pub fn downsample_standard(&self, k: usize, s: usize) -> Bitmap {
        assert!(k % 2 == 1);
        let pad = (k - 1) / 2;
        let ow = (self.w + s - 1) / s;
        let oh = (self.h + s - 1) / s;
        let mut out = Bitmap::new(ow, oh);
        for oy in 0..oh {
            for ox in 0..ow {
                'win: for dy in 0..k {
                    for dx in 0..k {
                        let ix = ox as isize * s as isize + dx as isize - pad as isize;
                        let iy = oy as isize * s as isize + dy as isize - pad as isize;
                        if ix >= 0
                            && iy >= 0
                            && (ix as usize) < self.w
                            && (iy as usize) < self.h
                            && self.get(ix as usize, iy as usize)
                        {
                            out.set(ox, oy);
                            break 'win;
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    fn random_bitmap(g: &mut Gen, w: usize, h: usize, p: f64) -> Bitmap {
        let mut b = Bitmap::new(w, h);
        for y in 0..h {
            for x in 0..w {
                if g.chance(p) {
                    b.set(x, y);
                }
            }
        }
        b
    }

    #[test]
    fn reset_clears_and_resizes() {
        let mut b = Bitmap::new(8, 8);
        b.set(3, 3);
        b.reset(8, 8);
        assert_eq!(b.count(), 0);
        b.reset(5, 3);
        assert_eq!((b.w, b.h), (5, 3));
        b.set(4, 2);
        assert_eq!(b.count(), 1);
        // Growing after a shrink works too.
        b.reset(16, 16);
        assert_eq!(b.count(), 0);
        b.set(15, 15);
        assert!(b.get(15, 15));
    }

    #[test]
    fn set_get_count() {
        let mut b = Bitmap::new(10, 7);
        assert_eq!(b.count(), 0);
        b.set(0, 0);
        b.set(9, 6);
        b.set(3, 2);
        assert!(b.get(0, 0) && b.get(9, 6) && b.get(3, 2));
        assert!(!b.get(1, 1));
        assert_eq!(b.count(), 3);
        b.clear(3, 2);
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn dilate_single_pixel_makes_kxk() {
        let mut b = Bitmap::new(9, 9);
        b.set(4, 4);
        let d = b.dilate(3);
        assert_eq!(d.count(), 9);
        for y in 3..=5 {
            for x in 3..=5 {
                assert!(d.get(x, y));
            }
        }
    }

    #[test]
    fn dilate_clips_at_border() {
        let mut b = Bitmap::new(5, 5);
        b.set(0, 0);
        let d = b.dilate(3);
        assert_eq!(d.count(), 4); // 2×2 corner
    }

    #[test]
    fn downsample_sparse_grid_rule() {
        let mut b = Bitmap::new(6, 6);
        b.set(1, 1); // grid (0,0)
        b.set(4, 5); // grid (2,2)
        let d = b.downsample_sparse(2);
        assert_eq!(d.w, 3);
        assert_eq!(d.count(), 2);
        assert!(d.get(0, 0) && d.get(2, 2));
        assert!(!d.get(1, 1));
    }

    #[test]
    fn standard_downsample_denser_than_sparse() {
        check("standard stride-2 ⊇ sparse stride-2", 64, |g| {
            let w = g.usize(4, 24);
            let h = g.usize(4, 24);
            let b = random_bitmap(g, w, h, 0.15);
            let sp = b.downsample_sparse(2);
            let st = b.downsample_standard(3, 2);
            // Every sparse-conv output location is also a standard-conv
            // output location (the k×k window contains the s×s grid since
            // k ≥ s when k=3, s=2 and pad=1).
            for (x, y) in sp.iter_set() {
                assert!(st.get(x, y), "sparse set at ({x},{y}) but standard not");
            }
            assert!(st.count() >= sp.count());
        });
    }

    #[test]
    fn dilation_monotone_and_submanifold_identity() {
        check("dilate ⊇ identity; submanifold = identity", 64, |g| {
            let w = g.usize(3, 20);
            let h = g.usize(3, 20);
            let b = random_bitmap(g, w, h, 0.2);
            let d = b.dilate(3);
            for (x, y) in b.iter_set() {
                assert!(d.get(x, y));
            }
            assert_eq!(b.submanifold(), b);
            assert!(d.count() >= b.count());
        });
    }

    #[test]
    fn copy_from_matches_and_reuses_storage() {
        check("copy_from == clone", 32, |g| {
            let w = g.usize(1, 20);
            let h = g.usize(1, 20);
            let b = random_bitmap(g, w, h, 0.3);
            let mut c = Bitmap::new(40, 40); // larger: storage must shrink-reuse
            c.set(5, 5);
            c.copy_from(&b);
            assert_eq!(c, b);
        });
    }

    #[test]
    fn dilate_into_matches_allocating_dilate() {
        check("dilate_into == dilate", 48, |g| {
            let w = g.usize(1, 24);
            let h = g.usize(1, 24);
            let k = [1, 3, 5][g.usize(0, 2)];
            let b = random_bitmap(g, w, h, 0.2);
            let mut out = Bitmap::new(3, 3); // dirty, wrong geometry
            out.set(0, 0);
            b.dilate_into(k, &mut out);
            assert_eq!(out, b.dilate(k));
        });
    }

    #[test]
    fn downsample_dirty_is_union_of_standard_and_sparse() {
        // The dirty-propagation rule for a stride-2 k×k conv is exactly
        // "value may change" (standard-downsample window rule) OR
        // "existence may change" (sparse-downsample occupancy rule).
        check("downsample_dirty == standard ∪ sparse", 48, |g| {
            let w = g.usize(1, 24);
            let h = g.usize(1, 24);
            let k = [1, 3, 5][g.usize(0, 2)];
            let b = random_bitmap(g, w, h, 0.2);
            let mut got = Bitmap::new(1, 1);
            b.downsample_dirty_into(k, &mut got);
            let st = b.downsample_standard(k, 2);
            let sp = b.downsample_sparse(2);
            assert_eq!((got.w, got.h), (st.w, st.h));
            for y in 0..got.h {
                for x in 0..got.w {
                    assert_eq!(
                        got.get(x, y),
                        st.get(x, y) || sp.get(x, y),
                        "mismatch at ({x},{y}) k={k} w={w} h={h}"
                    );
                }
            }
        });
    }

    #[test]
    fn nz_ratio() {
        let mut b = Bitmap::new(4, 4);
        b.set(0, 0);
        b.set(1, 1);
        assert!((b.nz_ratio() - 2.0 / 16.0).abs() < 1e-12);
    }
}
