// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Autoscaling + cost-profile demo: the serving pool grows under
//! deadline pressure, shrinks when idle, and a persisted cost profile
//! eliminates the cold-start probe phase on the next run.
//!
//! Two parts:
//! 1. a burst of requests slams a deliberately slow 1..4-replica class —
//!    the controller scales it up (backlog + deadline-drop pressure),
//!    then back down across the idle gap that follows; the scaling log
//!    and the replica-band column show the trajectory, and the
//!    conservation property (`served + dropped + deadline drops ==
//!    offered`) holds throughout,
//! 2. a two-class pool runs cold (cost-model probes), persists its
//!    learned profile through `CostProfile::save`/`load`, and a second
//!    run seeded from that file routes with **zero** probe requests.
//!
//! With `--report-out path` a machine-readable JSON summary is written —
//! CI greps it for `null` to catch NaN/inf leaking into reports.
//!
//! Run: `cargo run --release --example autoscale -- --dataset n_mnist`
//! (add `--smoke` for the quick CI-sized run)

use esda::coordinator::{
    run_pool, run_pool_source, AutoscaleConfig, Backend, BackendError, Classification,
    CostProfile, EventSource, Functional, IngestError, ReplicaPool, ReplicaSpec, ServerConfig,
    ServerResult, SourcedRequest, DEFAULT_TENANT,
};
use esda::events::DatasetProfile;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::json::Json;
use esda::util::Rng;
use std::time::{Duration, Instant};

/// A deliberately slow backend so load actually queues behind it.
struct Throttled {
    inner: Functional,
    delay: Duration,
}

impl Backend for Throttled {
    fn name(&self) -> &str {
        "throttled-functional"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
}

/// Burst-then-idle event source: emits each phase's requests
/// back-to-back (arrival = now), sleeping the phase's gap before moving
/// on — the load shape that makes an autoscaler earn its keep.
struct BurstSource {
    profile: DatasetProfile,
    rng: Rng,
    /// `(requests, idle gap after the phase)`.
    phases: Vec<(usize, Duration)>,
    phase: usize,
    emitted_in_phase: usize,
    emitted_total: usize,
}

impl EventSource for BurstSource {
    fn name(&self) -> &str {
        "burst"
    }
    fn geometry(&self) -> (usize, usize) {
        (self.profile.w, self.profile.h)
    }
    fn next_request(&mut self) -> Result<Option<SourcedRequest>, IngestError> {
        while self.phase < self.phases.len() {
            let (n, gap) = self.phases[self.phase];
            if self.emitted_in_phase < n {
                self.emitted_in_phase += 1;
                let label = self.emitted_total % self.profile.n_classes;
                self.emitted_total += 1;
                let events = self.profile.sample(label, &mut self.rng);
                let arrival = Instant::now();
                return Ok(Some(SourcedRequest {
                    label,
                    events,
                    arrival,
                    tenant: DEFAULT_TENANT,
                    model: 0,
                    stream: None,
                }));
            }
            std::thread::sleep(gap);
            self.phase += 1;
            self.emitted_in_phase = 0;
        }
        Ok(None)
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &["smoke"]).unwrap();
    let smoke = args.has("smoke");
    let name = args.get_or("dataset", "n_mnist");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            esda::events::repr::histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);

    // Part 1: scale up under deadline pressure, back down when idle.
    let burst = if smoke { 24 } else { 60 };
    let tail = 2;
    let n_offered = burst + tail;
    let source = BurstSource {
        profile: profile.clone(),
        rng: Rng::new(7),
        // Burst, a long idle gap (several autoscaler windows), then a
        // trickle so the run outlives the scale-down.
        phases: vec![
            (burst, Duration::from_millis(if smoke { 600 } else { 900 })),
            (tail, Duration::ZERO),
        ],
        phase: 0,
        emitted_in_phase: 0,
        emitted_total: 0,
    };
    let qw = qnet.clone();
    let pool = ReplicaPool::build(vec![ReplicaSpec::new("work", 1, 1, move |_| {
        Ok(Box::new(Throttled {
            inner: Functional::new(qw.clone()),
            delay: Duration::from_millis(3),
        }))
    })
    .with_max_replicas(4)])
    .expect("pool build");
    let cfg = ServerConfig {
        queue_depth: 32,
        slo: Some(Duration::from_millis(150)),
        autoscale: Some(AutoscaleConfig {
            interval: Duration::from_millis(10),
            window: Duration::from_millis(100),
            high_backlog: 2.0,
            low_util: 0.3,
        }),
        ..Default::default()
    };
    let r = run_pool_source(Box::new(source), &pool, &cfg).expect("autoscaled serve");
    let m = &r.metrics;
    println!("== burst into work=1..4 (3 ms/req, SLO 150 ms) ==");
    println!(
        "  {} served / {} offered | {} deadline drop(s) | {} scaling event(s)",
        m.total,
        m.offered(),
        m.deadline_drops(),
        m.scaling_events.len(),
    );
    for line in esda::report::scaling_log(m) {
        println!("  {line}");
    }
    if let Some(line) = esda::report::slo_line(m) {
        println!("  {line}");
    }
    println!("{}", esda::report::pool_table(m).render());

    // The demo is also an acceptance check: conservation holds, the
    // class actually scaled, and the band was respected.
    assert_eq!(
        m.total + m.dropped + m.deadline_drops(),
        n_offered,
        "conservation must hold under autoscaling"
    );
    let c = &m.per_class[0];
    assert!(
        c.replicas_peak >= 2,
        "the burst must scale the class up (peak {})",
        c.replicas_peak
    );
    assert!(
        (c.replicas_min..=c.replicas_max).contains(&c.replicas)
            && c.replicas_peak <= c.replicas_max,
        "replica counts must stay inside the band"
    );
    let scaled_down = m.scaling_events.iter().any(|e| e.to < e.from);
    assert!(scaled_down, "the idle gap must scale the class back down");

    // Part 2: cost-profile persistence kills the cold start.
    let (qf, qs) = (qnet.clone(), qnet);
    let two_class_pool = || {
        let (qf, qs) = (qf.clone(), qs.clone());
        ReplicaPool::build(vec![
            ReplicaSpec::new("fast", 1, 4, move |_| Ok(Box::new(Functional::new(qf.clone())))),
            ReplicaSpec::new("slow", 1, 1, move |_| {
                Ok(Box::new(Throttled {
                    inner: Functional::new(qs.clone()),
                    delay: Duration::from_millis(3),
                }))
            }),
        ])
        .expect("pool build")
    };
    let cfg2 = ServerConfig {
        n_requests: if smoke { 24 } else { 48 },
        seed: 9,
        queue_depth: 8,
        ..Default::default()
    };
    let probes = |r: &ServerResult| -> usize {
        r.metrics.per_class.iter().map(|c| c.unseeded).sum()
    };
    let cold = run_pool(&profile, &two_class_pool(), &cfg2).expect("cold run");
    let profile_path =
        std::env::temp_dir().join(format!("esda_autoscale_profile_{}.json", std::process::id()));
    cold.metrics.cost_profile.save(&profile_path).expect("save profile");
    let (seeded_profile, warning) = CostProfile::load(&profile_path).expect("load profile");
    assert!(warning.is_none(), "a freshly saved profile must load clean");
    let warm = run_pool(
        &profile,
        &two_class_pool(),
        &ServerConfig { cost_profile: Some(seeded_profile), ..cfg2.clone() },
    )
    .expect("seeded run");
    println!("== cost-profile persistence (fast+slow pool) ==");
    println!(
        "  cold run: {} probe request(s) before the routers seeded",
        probes(&cold)
    );
    println!(
        "  seeded run ({}): {} probe request(s)",
        profile_path.display(),
        probes(&warm)
    );
    assert!(probes(&cold) >= 1, "a cold pool must probe");
    assert_eq!(probes(&warm), 0, "a seeded pool must not probe at all");

    // Machine-readable summary (CI greps this for `null`).
    if let Some(out) = args.get("report-out") {
        let wall = m.wall_seconds();
        let doc = Json::obj(vec![
            ("offered", Json::Num(n_offered as f64)),
            ("served", Json::Num(m.total as f64)),
            ("queue_drops", Json::Num(m.dropped as f64)),
            ("deadline_drops", Json::Num(m.deadline_drops() as f64)),
            (
                "conservation_ok",
                Json::Bool(m.total + m.dropped + m.deadline_drops() == n_offered),
            ),
            ("slo_attainment", Json::Num(m.slo_attainment().unwrap_or(0.0))),
            ("scaling_events", Json::Num(m.scaling_events.len() as f64)),
            ("replicas_final", Json::Num(c.replicas as f64)),
            ("replicas_peak", Json::Num(c.replicas_peak as f64)),
            ("replicas_min", Json::Num(c.replicas_min as f64)),
            ("replicas_max", Json::Num(c.replicas_max as f64)),
            ("class_utilization", Json::Num(c.utilization(wall))),
            ("probes_cold", Json::Num(probes(&cold) as f64)),
            ("probes_seeded", Json::Num(probes(&warm) as f64)),
        ]);
        std::fs::write(out, doc.to_string()).expect("write report");
        println!("report written -> {out}");
    }
    std::fs::remove_file(&profile_path).ok();
}
