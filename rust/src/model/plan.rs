//! Compile-once / execute-many functional execution.
//!
//! The paper's premise is compose-once, stream-forever: modules are
//! parametrized and wired a single time, then event batches flow through a
//! fixed dataflow with no per-inference setup. [`super::exec`] (the oracle)
//! does the opposite — it re-walks the op program, re-resolves quantized
//! weights, and allocates fresh token/feature vectors on every request.
//! This module splits that into:
//!
//! - [`ExecPlan`] — built **once** per network from a [`QuantizedNet`]:
//!   ops lowered to a flat step list with pre-resolved weight/requant
//!   references (no `Option` unwrapping on the hot path), weights laid out
//!   for cache-friendly inner loops (the FC matrix is stored transposed;
//!   pointwise loops run ci-outer/co-inner over the native `[ci][co]`
//!   rows), and per-step geometry / scratch-size descriptors.
//! - [`ExecCtx`] — a reusable per-worker buffer arena: double-buffered
//!   token/feature maps, a residual fork pool, the [`NeighborIndex`]
//!   rulebook scratch, and the int32 accumulators. After a warm-up
//!   inference sizes the buffers, steady-state execution performs **zero
//!   heap allocations** (enforced by `rust/tests/exec_plan.rs` with a
//!   counting allocator).
//!
//! Execution is bit-exact with [`super::exec::forward_i8`]: both paths run
//! the same integer kernels (`sparse::conv`), property-tested across random
//! networks and inputs in `rust/tests/exec_plan.rs`.

use super::exec::argmax;
use super::graph::Op;
use super::quant::QuantizedNet;
use crate::sparse::conv;
use crate::sparse::quant::Requant;
use crate::sparse::rulebook::NeighborIndex;
use crate::sparse::{Bitmap, SparseMap};

/// Pre-resolved weights for one step (cloned out of the `QuantizedNet` at
/// compile time so execution never touches `Option<QuantOpWeights>`).
#[derive(Clone, Debug)]
pub struct StepWeights {
    pub w: Vec<i8>,
    pub b: Vec<i32>,
    pub rq: Requant,
}

/// One lowered execution step. Weighted variants embed their weights —
/// resolving them is a compile-time, not a per-request, operation.
#[derive(Clone, Debug)]
pub enum StepKind {
    /// 1×1 pointwise conv.
    Conv1x1(StepWeights),
    /// Full k×k submanifold conv, stride 1 (the stem).
    ConvKxKS1 { k: usize, w: StepWeights },
    /// Full k×k sparse conv, stride 2.
    ConvKxKS2 { k: usize, w: StepWeights },
    /// Depthwise k×k submanifold conv, stride 1.
    DwConvS1 { k: usize, w: StepWeights },
    /// Depthwise k×k sparse conv, stride 2.
    DwConvS2 { k: usize, w: StepWeights },
    /// Push a copy of the stream for an identity shortcut.
    ResFork,
    /// Pop the shortcut and add it (saturating int8).
    ResAdd,
    /// Global average pool over tokens (map → int32 vector).
    GlobalPool,
    /// FC head; weights stored **transposed** (`wt[co * cin + ci]`).
    Fc(StepWeights),
}

/// One step plus its geometry descriptor (input/output spatial size and
/// channel counts — `cout` doubles as the accumulator scratch size).
#[derive(Clone, Debug)]
pub struct PlanStep {
    pub kind: StepKind,
    pub in_w: usize,
    pub in_h: usize,
    pub cin: usize,
    pub out_w: usize,
    pub out_h: usize,
    pub cout: usize,
}

/// A compiled execution plan: build once per network, execute per request
/// through a reusable [`ExecCtx`].
#[derive(Clone, Debug)]
pub struct ExecPlan {
    pub steps: Vec<PlanStep>,
    /// Scale mapping f32 input → int8 (from calibration).
    pub input_scale: f32,
    /// Expected input geometry.
    pub in_w: usize,
    pub in_h: usize,
    pub cin: usize,
    /// Logit arity of the FC head.
    pub n_classes: usize,
    /// Largest accumulator any step needs (scratch-size descriptor).
    pub max_cout: usize,
    /// Deepest simultaneous residual-fork nesting.
    pub fork_depth: usize,
    /// FNV-1a over the lowered steps (dims + weights + biases + input
    /// scale). A [`DeltaCache`] is stamped with this so cached activations
    /// from a *different* network are never treated as a previous window.
    pub fingerprint: u64,
}

impl ExecPlan {
    /// Lower a quantized network into a flat step list. Panics on a
    /// malformed network (missing quantized weights, unbalanced residual
    /// forks, or a program that does not end in `GlobalPool → Fc`) — the
    /// same conditions the oracle would panic on mid-request, surfaced at
    /// compile time instead.
    pub fn compile(qnet: &QuantizedNet) -> ExecPlan {
        let spec = &qnet.spec;
        let ops = spec.ops();
        assert!(
            matches!(ops.last(), Some(Op::Fc { .. })),
            "ExecPlan requires a classification network ending in an FC head"
        );
        let weights_of = |i: usize| -> StepWeights {
            let q = qnet.per_op[i]
                .as_ref()
                // lint:allow(panic): compile-time invariant, documented above
                .unwrap_or_else(|| panic!("op {i} has no quantized weights"));
            StepWeights { w: q.w.clone(), b: q.b.clone(), rq: q.rq }
        };
        let mut steps = Vec::with_capacity(ops.len());
        let (mut w, mut h) = (spec.w, spec.h);
        let mut c = spec.cin;
        let mut depth = 0usize;
        let mut fork_depth = 0usize;
        let mut max_cout = 0usize;
        for (i, op) in ops.iter().enumerate() {
            let (in_w, in_h, cin) = (w, h, c);
            let kind = match *op {
                Op::Conv1x1 { cout, .. } => {
                    c = cout;
                    StepKind::Conv1x1(weights_of(i))
                }
                Op::ConvKxK { k, cout, stride, .. } => {
                    c = cout;
                    if stride == 1 {
                        StepKind::ConvKxKS1 { k, w: weights_of(i) }
                    } else {
                        w = (w + 1) / 2;
                        h = (h + 1) / 2;
                        StepKind::ConvKxKS2 { k, w: weights_of(i) }
                    }
                }
                Op::DwConv { k, stride, .. } => {
                    if stride == 1 {
                        StepKind::DwConvS1 { k, w: weights_of(i) }
                    } else {
                        w = (w + 1) / 2;
                        h = (h + 1) / 2;
                        StepKind::DwConvS2 { k, w: weights_of(i) }
                    }
                }
                Op::ResFork => {
                    depth += 1;
                    fork_depth = fork_depth.max(depth);
                    StepKind::ResFork
                }
                Op::ResAdd => {
                    assert!(depth > 0, "ResAdd without matching ResFork at op {i}");
                    depth -= 1;
                    StepKind::ResAdd
                }
                Op::GlobalPool { .. } => StepKind::GlobalPool,
                Op::Fc { cin, cout } => {
                    let q = qnet.per_op[i]
                        .as_ref()
                        // lint:allow(panic): compile-time invariant, see above
                        .unwrap_or_else(|| panic!("FC op {i} has no quantized weights"));
                    assert_eq!(q.w.len(), cin * cout, "FC weight shape mismatch");
                    // Transpose to `wt[co * cin + ci]` so each logit's dot
                    // product walks one contiguous row.
                    let mut wt = vec![0i8; cin * cout];
                    for ci in 0..cin {
                        for co in 0..cout {
                            wt[co * cin + ci] = q.w[ci * cout + co];
                        }
                    }
                    c = cout;
                    StepKind::Fc(StepWeights { w: wt, b: q.b.clone(), rq: q.rq })
                }
            };
            max_cout = max_cout.max(c);
            steps.push(PlanStep { kind, in_w, in_h, cin, out_w: w, out_h: h, cout: c });
        }
        assert_eq!(depth, 0, "unbalanced ResFork/ResAdd");
        let fingerprint = fingerprint_steps(&steps, qnet.input_scale);
        ExecPlan {
            steps,
            input_scale: qnet.input_scale,
            in_w: spec.w,
            in_h: spec.h,
            cin: spec.cin,
            n_classes: spec.n_classes,
            max_cout,
            fork_depth,
            fingerprint,
        }
    }

    // lint: hot-path — steady-state inference must stay allocation-free
    /// Run the plan over a float input, reusing `ctx`'s arena; returns the
    /// int32 logits (borrowed from the context — copy them out if they must
    /// outlive the next execution).
    ///
    /// Only the channel count is checked (matching the oracle,
    /// [`super::exec::forward_i8`]): every kernel derives its geometry from
    /// the input map, so off-spec resolutions execute fine — the plan's
    /// `in_w`/`in_h` and per-step descriptors are the *expected* geometry,
    /// for sizing and diagnostics.
    pub fn execute<'c>(&self, ctx: &'c mut ExecCtx, input: &SparseMap<f32>) -> &'c [i32] {
        assert_eq!(input.c, self.cin, "input channels mismatch");
        quantize_into(self.input_scale, input, &mut ctx.cur);
        self.run_steps(ctx, None);
        &ctx.logits
    }

    /// Run the step list over the quantized input already in `ctx.cur`.
    /// With `store`, each conv step's output is additionally snapshotted
    /// into the cache's per-layer arena (the full-recompute half of the
    /// delta path: a fallback still has to refresh the cached window).
    fn run_steps(&self, ctx: &mut ExecCtx, mut store: Option<&mut DeltaCache>) {
        ctx.fork_top = 0;
        for (si, step) in self.steps.iter().enumerate() {
            let mut snapshot = false;
            match step.kind {
                StepKind::Conv1x1(ref sw) => {
                    conv::conv1x1_i8_into(
                        &ctx.cur,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    snapshot = true;
                }
                StepKind::ConvKxKS1 { k, w: ref sw } => {
                    conv::conv_kxk_s1_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    snapshot = true;
                }
                StepKind::ConvKxKS2 { k, w: ref sw } => {
                    conv::conv_kxk_s2_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.ds,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    snapshot = true;
                }
                StepKind::DwConvS1 { k, w: ref sw } => {
                    conv::dwconv_kxk_s1_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    snapshot = true;
                }
                StepKind::DwConvS2 { k, w: ref sw } => {
                    conv::dwconv_kxk_s2_i8_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        &sw.rq,
                        &mut ctx.idx,
                        &mut ctx.ds,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    snapshot = true;
                }
                StepKind::ResFork => {
                    if ctx.forks.len() == ctx.fork_top {
                        ctx.forks.push(SparseMap::empty(0, 0, 0));
                    }
                    let top = ctx.fork_top;
                    ctx.forks[top].copy_from(&ctx.cur);
                    ctx.fork_top += 1;
                }
                StepKind::ResAdd => {
                    // lint:allow(panic): plan compiled with balanced forks (compile asserts)
                    let top = ctx.fork_top.checked_sub(1).expect("ResAdd without ResFork");
                    ctx.fork_top = top;
                    conv::residual_add_i8_inplace(&mut ctx.cur, &ctx.forks[top]);
                }
                StepKind::GlobalPool => {
                    conv::global_avg_pool_i8_into(&ctx.cur, &mut ctx.acc64, &mut ctx.pooled);
                }
                StepKind::Fc(ref sw) => {
                    conv::fc_i8_t_into(&ctx.pooled, &sw.w, &sw.b, step.cout, &mut ctx.logits);
                }
            }
            if snapshot {
                if let Some(c) = store.as_deref_mut() {
                    c.layers[si].copy_from(&ctx.cur);
                }
            }
        }
    }

    /// Classify: execute and argmax the logits.
    pub fn classify(&self, ctx: &mut ExecCtx, input: &SparseMap<f32>) -> usize {
        argmax(self.execute(ctx, input))
    }

    /// Incremental execution across overlapping windows of one stream.
    ///
    /// Diffs the new window's quantized active set against the previous
    /// window cached in `cache` (both token lists are in strictly
    /// increasing ravel order, so the diff is a linear merge), seeds a
    /// dirty-site frontier, and propagates only changed sites layer by
    /// layer: stride-1 receptive fields dilate the frontier
    /// ([`Bitmap::dilate_into`]), stride-2 steps downsample it
    /// ([`Bitmap::downsample_dirty_into`]), and each conv kernel recomputes
    /// dirty outputs while copying clean ones from the cached per-layer
    /// activations (`sparse::conv::*_delta_into`). Residual forks/adds,
    /// pooling, and the FC head always run fully — they are cheap relative
    /// to the convs and keep the path trivially exact.
    ///
    /// Falls back to a full recompute (which also refreshes the cache) when
    /// the cache is cold or stamped by another plan, the input geometry
    /// changed, or the changed-site fraction exceeds `max_frac`. The result
    /// is **bit-identical** to [`ExecPlan::execute`] in every case
    /// (property-tested in `rust/tests/exec_plan.rs`), and like `execute`
    /// the steady state performs zero heap allocations — `cache`, too, is
    /// an arena.
    pub fn execute_delta<'c>(
        &self,
        ctx: &'c mut ExecCtx,
        cache: &mut DeltaCache,
        input: &SparseMap<f32>,
        max_frac: f64,
    ) -> (&'c [i32], DeltaOutcome) {
        assert_eq!(input.c, self.cin, "input channels mismatch");
        cache.layers.resize_with(self.steps.len(), || SparseMap::empty(0, 0, 0));
        quantize_into(self.input_scale, input, &mut ctx.cur);
        let reason = if !cache.valid || cache.fingerprint != self.fingerprint {
            Some(FullReason::ColdCache)
        } else if (cache.in_w, cache.in_h, cache.cin) != (input.w, input.h, input.c) {
            Some(FullReason::Geometry)
        } else {
            None
        };
        if let Some(r) = reason {
            self.run_full_storing(ctx, cache);
            return (&ctx.logits, DeltaOutcome::Full(r));
        }
        // Layer-0 frontier: sites whose presence or quantized features
        // changed since the previous window.
        let dirty_sites = diff_into(&ctx.cur, &cache.prev_in, &mut cache.dirty);
        let input_sites = ctx.cur.nnz();
        if dirty_sites as f64 > max_frac * input_sites.max(1) as f64 {
            self.run_full_storing(ctx, cache);
            return (&ctx.logits, DeltaOutcome::Full(FullReason::OverThreshold));
        }
        cache.prev_in.copy_from(&ctx.cur);
        ctx.fork_top = 0;
        let mut recomputed = 0usize;
        let mut total_sites = 0usize;
        for (si, step) in self.steps.iter().enumerate() {
            match step.kind {
                StepKind::Conv1x1(ref sw) => {
                    // Pointwise: the output frontier equals the input
                    // frontier — no propagation needed.
                    recomputed += conv::conv1x1_i8_delta_into(
                        &ctx.cur,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &cache.dirty,
                        &cache.layers[si],
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    total_sites += ctx.cur.nnz();
                    cache.layers[si].copy_from(&ctx.cur);
                }
                StepKind::ConvKxKS1 { k, w: ref sw } => {
                    cache.dirty.dilate_into(k, &mut cache.dirty_next);
                    recomputed += conv::conv_kxk_s1_i8_delta_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &cache.dirty_next,
                        &cache.layers[si],
                        &mut ctx.idx,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    total_sites += ctx.cur.nnz();
                    cache.layers[si].copy_from(&ctx.cur);
                    std::mem::swap(&mut cache.dirty, &mut cache.dirty_next);
                }
                StepKind::ConvKxKS2 { k, w: ref sw } => {
                    cache.dirty.downsample_dirty_into(k, &mut cache.dirty_next);
                    recomputed += conv::conv_kxk_s2_i8_delta_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        step.cout,
                        &sw.rq,
                        &cache.dirty_next,
                        &cache.layers[si],
                        &mut ctx.idx,
                        &mut ctx.ds,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    total_sites += ctx.cur.nnz();
                    cache.layers[si].copy_from(&ctx.cur);
                    std::mem::swap(&mut cache.dirty, &mut cache.dirty_next);
                }
                StepKind::DwConvS1 { k, w: ref sw } => {
                    cache.dirty.dilate_into(k, &mut cache.dirty_next);
                    recomputed += conv::dwconv_kxk_s1_i8_delta_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        &sw.rq,
                        &cache.dirty_next,
                        &cache.layers[si],
                        &mut ctx.idx,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    total_sites += ctx.cur.nnz();
                    cache.layers[si].copy_from(&ctx.cur);
                    std::mem::swap(&mut cache.dirty, &mut cache.dirty_next);
                }
                StepKind::DwConvS2 { k, w: ref sw } => {
                    cache.dirty.downsample_dirty_into(k, &mut cache.dirty_next);
                    recomputed += conv::dwconv_kxk_s2_i8_delta_into(
                        &ctx.cur,
                        k,
                        &sw.w,
                        &sw.b,
                        &sw.rq,
                        &cache.dirty_next,
                        &cache.layers[si],
                        &mut ctx.idx,
                        &mut ctx.ds,
                        &mut ctx.acc,
                        &mut ctx.next,
                    );
                    std::mem::swap(&mut ctx.cur, &mut ctx.next);
                    total_sites += ctx.cur.nnz();
                    cache.layers[si].copy_from(&ctx.cur);
                    std::mem::swap(&mut cache.dirty, &mut cache.dirty_next);
                }
                StepKind::ResFork => {
                    if ctx.forks.len() == ctx.fork_top {
                        ctx.forks.push(SparseMap::empty(0, 0, 0));
                    }
                    let top = ctx.fork_top;
                    ctx.forks[top].copy_from(&ctx.cur);
                    ctx.fork_top += 1;
                }
                StepKind::ResAdd => {
                    // Run fully: the fork-to-add span is stride-1 only
                    // (ResAdd asserts token equality), so the frontier at
                    // the add is a superset of the frontier at the fork —
                    // every site the add could change is already dirty.
                    // lint:allow(panic): plan compiled with balanced forks (compile asserts)
                    let top = ctx.fork_top.checked_sub(1).expect("ResAdd without ResFork");
                    ctx.fork_top = top;
                    conv::residual_add_i8_inplace(&mut ctx.cur, &ctx.forks[top]);
                }
                StepKind::GlobalPool => {
                    conv::global_avg_pool_i8_into(&ctx.cur, &mut ctx.acc64, &mut ctx.pooled);
                }
                StepKind::Fc(ref sw) => {
                    conv::fc_i8_t_into(&ctx.pooled, &sw.w, &sw.b, step.cout, &mut ctx.logits);
                }
            }
        }
        let outcome = DeltaOutcome::Delta { dirty: dirty_sites, input_sites, recomputed, total_sites };
        (&ctx.logits, outcome)
    }

    /// Classify incrementally: [`ExecPlan::execute_delta`] + argmax.
    pub fn classify_delta(
        &self,
        ctx: &mut ExecCtx,
        cache: &mut DeltaCache,
        input: &SparseMap<f32>,
        max_frac: f64,
    ) -> (usize, DeltaOutcome) {
        let (logits, outcome) = self.execute_delta(ctx, cache, input, max_frac);
        (argmax(logits), outcome)
    }

    /// Full recompute that also refreshes `cache` with the new window: the
    /// quantized input (already in `ctx.cur`), every conv layer's output,
    /// and the validity/geometry/plan stamps.
    fn run_full_storing(&self, ctx: &mut ExecCtx, cache: &mut DeltaCache) {
        cache.valid = true;
        cache.fingerprint = self.fingerprint;
        cache.in_w = ctx.cur.w;
        cache.in_h = ctx.cur.h;
        cache.cin = ctx.cur.c;
        cache.prev_in.copy_from(&ctx.cur);
        self.run_steps(ctx, Some(cache));
    }
    // lint: hot-path end
}

/// Why a delta execution fell back to a full recompute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FullReason {
    /// First window of a stream, an invalidated cache, or a cache stamped
    /// by a different plan.
    ColdCache,
    /// Input geometry changed since the cached window.
    Geometry,
    /// The changed-site fraction exceeded the configured `max_frac`.
    OverThreshold,
}

/// What [`ExecPlan::execute_delta`] did for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeltaOutcome {
    /// The delta path ran: `dirty` of `input_sites` layer-0 sites seeded
    /// the frontier; `recomputed` of `total_sites` conv output sites were
    /// recomputed (the rest were copied from the cached window).
    Delta { dirty: usize, input_sites: usize, recomputed: usize, total_sites: usize },
    /// Full recompute (cache refreshed along the way).
    Full(FullReason),
}

impl DeltaOutcome {
    /// Fraction of layer-0 sites that changed (1.0 for a full recompute).
    pub fn dirty_frac(&self) -> f64 {
        match *self {
            DeltaOutcome::Delta { dirty, input_sites, .. } => {
                dirty as f64 / input_sites.max(1) as f64
            }
            DeltaOutcome::Full(_) => 1.0,
        }
    }

    /// Fraction of conv output sites recomputed (1.0 for a full recompute).
    pub fn recomputed_frac(&self) -> f64 {
        match *self {
            DeltaOutcome::Delta { recomputed, total_sites, .. } => {
                recomputed as f64 / total_sites.max(1) as f64
            }
            DeltaOutcome::Full(_) => 1.0,
        }
    }

    pub fn is_delta(&self) -> bool {
        matches!(self, DeltaOutcome::Delta { .. })
    }
}

/// Per-stream delta-execution cache: the previous window's quantized input,
/// each conv layer's output, and the dirty-frontier double buffer. Same
/// arena discipline as [`ExecCtx`] — the first window sizes the buffers,
/// subsequent windows run allocation-free.
#[derive(Debug)]
pub struct DeltaCache {
    valid: bool,
    fingerprint: u64,
    in_w: usize,
    in_h: usize,
    cin: usize,
    prev_in: SparseMap<i8>,
    layers: Vec<SparseMap<i8>>,
    dirty: Bitmap,
    dirty_next: Bitmap,
}

impl DeltaCache {
    pub fn new() -> DeltaCache {
        DeltaCache {
            valid: false,
            fingerprint: 0,
            in_w: 0,
            in_h: 0,
            cin: 0,
            prev_in: SparseMap::empty(0, 0, 0),
            layers: Vec::new(),
            dirty: Bitmap::new(0, 0),
            dirty_next: Bitmap::new(0, 0),
        }
    }

    /// Drop the cached window: the next `execute_delta` takes the
    /// cold-cache full path. Buffers are kept for reuse.
    pub fn invalidate(&mut self) {
        self.valid = false;
    }
}

impl Default for DeltaCache {
    fn default() -> Self {
        DeltaCache::new()
    }
}

// lint: hot-path — the window diff runs once per request on the delta path
/// Mark every site whose presence or features differ between two
/// ravel-ordered maps of identical geometry; returns the marked count.
fn diff_into(new: &SparseMap<i8>, prev: &SparseMap<i8>, dirty: &mut Bitmap) -> usize {
    debug_assert_eq!((new.w, new.h, new.c), (prev.w, prev.h, prev.c));
    dirty.reset(new.w, new.h);
    let (nn, np) = (new.tokens.len(), prev.tokens.len());
    let (mut i, mut j) = (0usize, 0usize);
    let mut n = 0usize;
    while i < nn || j < np {
        let rn = if i < nn { new.tokens[i].ravel(new.w) } else { usize::MAX };
        let rp = if j < np { prev.tokens[j].ravel(new.w) } else { usize::MAX };
        if rn == rp {
            if new.feat(i) != prev.feat(j) {
                let t = new.tokens[i];
                dirty.set(t.x as usize, t.y as usize);
                n += 1;
            }
            i += 1;
            j += 1;
        } else if rn < rp {
            let t = new.tokens[i];
            dirty.set(t.x as usize, t.y as usize);
            n += 1;
            i += 1;
        } else {
            let t = prev.tokens[j];
            dirty.set(t.x as usize, t.y as usize);
            n += 1;
            j += 1;
        }
    }
    n
}
// lint: hot-path end

/// FNV-1a plan fingerprint: step tags, geometry, weights, biases, and the
/// input scale. Collisions are astronomically unlikely and the stakes are
/// low (the fingerprint only guards a cache shared across plans).
fn fingerprint_steps(steps: &[PlanStep], input_scale: f32) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |h: &mut u64, v: u64| {
        *h ^= v;
        *h = h.wrapping_mul(0x100000001b3);
    };
    mix(&mut h, input_scale.to_bits() as u64);
    for step in steps {
        let (tag, k, sw) = match step.kind {
            StepKind::Conv1x1(ref sw) => (1u64, 1usize, Some(sw)),
            StepKind::ConvKxKS1 { k, w: ref sw } => (2, k, Some(sw)),
            StepKind::ConvKxKS2 { k, w: ref sw } => (3, k, Some(sw)),
            StepKind::DwConvS1 { k, w: ref sw } => (4, k, Some(sw)),
            StepKind::DwConvS2 { k, w: ref sw } => (5, k, Some(sw)),
            StepKind::ResFork => (6, 0, None),
            StepKind::ResAdd => (7, 0, None),
            StepKind::GlobalPool => (8, 0, None),
            StepKind::Fc(ref sw) => (9, 0, Some(sw)),
        };
        mix(&mut h, tag);
        mix(&mut h, k as u64);
        mix(&mut h, (step.in_w ^ (step.in_h << 16) ^ (step.cin << 32)) as u64);
        mix(&mut h, (step.out_w ^ (step.out_h << 16) ^ (step.cout << 32)) as u64);
        if let Some(sw) = sw {
            for &b in &sw.w {
                mix(&mut h, b as u8 as u64);
            }
            for &b in &sw.b {
                mix(&mut h, b as u32 as u64);
            }
        }
    }
    h
}

// lint: hot-path — runs once per request before the step list
/// Quantize a float input map into `out` with the network's input scale —
/// the arena variant of [`super::exec::quantize_input`].
fn quantize_into(scale: f32, input: &SparseMap<f32>, out: &mut SparseMap<i8>) {
    out.reset(input.w, input.h, input.c);
    out.tokens.extend_from_slice(&input.tokens);
    out.feats.reserve(input.feats.len());
    for &v in &input.feats {
        out.feats.push(((v / scale).round() as i32).clamp(-128, 127) as i8);
    }
}
// lint: hot-path end

/// Per-worker execution context: the buffer arena a plan executes through.
/// Create once (cheap — all buffers start empty), reuse for every request;
/// the first execution sizes the buffers and subsequent ones run
/// allocation-free. A context is plan-agnostic: it can be shared across
/// plans (buffers regrow as needed).
#[derive(Debug)]
pub struct ExecCtx {
    /// Double-buffered token/feature maps (current layer input / output).
    cur: SparseMap<i8>,
    next: SparseMap<i8>,
    /// Residual shortcut pool, `fork_top` slots live.
    forks: Vec<SparseMap<i8>>,
    fork_top: usize,
    /// Rulebook scratch: dense coordinate → token-index grid.
    idx: NeighborIndex,
    /// Stride-2 downsample bitmap scratch.
    ds: Bitmap,
    /// int32 accumulator (sized to the plan's `max_cout`).
    acc: Vec<i32>,
    /// i64 pooling accumulator.
    acc64: Vec<i64>,
    /// Pooled vector and logits.
    pooled: Vec<i32>,
    logits: Vec<i32>,
}

impl ExecCtx {
    pub fn new() -> ExecCtx {
        ExecCtx {
            cur: SparseMap::empty(0, 0, 0),
            next: SparseMap::empty(0, 0, 0),
            forks: Vec::new(),
            fork_top: 0,
            idx: NeighborIndex::new(),
            ds: Bitmap::new(0, 0),
            acc: Vec::new(),
            acc64: Vec::new(),
            pooled: Vec::new(),
            logits: Vec::new(),
        }
    }
}

impl Default for ExecCtx {
    fn default() -> Self {
        ExecCtx::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{repr::histogram2_norm, DatasetProfile};
    use crate::model::exec::{classify_i8, forward_i8};
    use crate::model::quant::quantize_network;
    use crate::model::weights::FloatWeights;
    use crate::model::NetworkSpec;
    use crate::util::Rng;

    fn small_input(seed: u64) -> SparseMap<f32> {
        let p = DatasetProfile::n_mnist();
        let mut rng = Rng::new(seed);
        let es = p.sample(seed as usize % p.n_classes, &mut rng);
        histogram2_norm(&es, p.w, p.h, 8.0)
    }

    fn tiny_qnet(seed: u64) -> QuantizedNet {
        let spec = NetworkSpec::tiny(34, 34, 5);
        let w = FloatWeights::random(&spec, seed);
        let calib: Vec<SparseMap<f32>> = (0..3).map(small_input).collect();
        quantize_network(&spec, &w, &calib)
    }

    #[test]
    fn plan_structure_mirrors_ops() {
        let qnet = tiny_qnet(1);
        let plan = ExecPlan::compile(&qnet);
        assert_eq!(plan.steps.len(), qnet.spec.ops().len());
        assert_eq!(plan.n_classes, 5);
        assert_eq!(plan.fork_depth, 1); // tiny has one residual block
        assert!(plan.max_cout >= 8);
        // Geometry chains: each step's input is the previous step's output.
        for pair in plan.steps.windows(2) {
            assert_eq!((pair[0].out_w, pair[0].out_h), (pair[1].in_w, pair[1].in_h));
        }
        // The stride-2 block halves resolution exactly once in tiny.
        let last = plan.steps.last().unwrap();
        assert_eq!((last.out_w, last.out_h), (17, 17));
    }

    #[test]
    fn plan_execution_matches_oracle_logits() {
        let qnet = tiny_qnet(7);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        for s in 20..26u64 {
            let input = small_input(s);
            let want = forward_i8(&qnet, &input);
            let got = plan.execute(&mut ctx, &input).to_vec();
            assert_eq!(got, want, "seed {s}");
            assert_eq!(plan.classify(&mut ctx, &input), classify_i8(&qnet, &input));
        }
    }

    #[test]
    fn context_is_reusable_across_plans() {
        let qa = tiny_qnet(3);
        let qb = tiny_qnet(4);
        let pa = ExecPlan::compile(&qa);
        let pb = ExecPlan::compile(&qb);
        let mut ctx = ExecCtx::new();
        let input = small_input(9);
        // Interleave two plans through one context: no cross-talk.
        for _ in 0..2 {
            assert_eq!(pa.execute(&mut ctx, &input).to_vec(), forward_i8(&qa, &input));
            assert_eq!(pb.execute(&mut ctx, &input).to_vec(), forward_i8(&qb, &input));
        }
    }

    #[test]
    fn empty_input_classifies_without_panic() {
        let qnet = tiny_qnet(5);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        let empty: SparseMap<f32> = SparseMap::empty(34, 34, 2);
        let got = plan.execute(&mut ctx, &empty).to_vec();
        assert_eq!(got, forward_i8(&qnet, &empty));
    }

    /// Overlapping next window: flip a few sites' presence, rewrite a few
    /// features (in ravel order, so `push` stays happy).
    fn perturb_input(rng: &mut Rng, prev: &SparseMap<f32>, p: f64) -> SparseMap<f32> {
        let mut m: SparseMap<f32> = SparseMap::empty(prev.w, prev.h, prev.c);
        for y in 0..prev.h {
            for x in 0..prev.w {
                let at = prev.find(x as u16, y as u16);
                let present = if rng.chance(p) { at.is_none() } else { at.is_some() };
                if !present {
                    continue;
                }
                let f: Vec<f32> = match at {
                    Some(i) if !rng.chance(p) => prev.feat(i).to_vec(),
                    _ => (0..prev.c).map(|_| rng.f64() as f32).collect(),
                };
                m.push(crate::sparse::Token::new(x as u16, y as u16), &f);
            }
        }
        m
    }

    #[test]
    fn delta_stream_is_bit_exact_and_hits() {
        let qnet = tiny_qnet(11);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        let mut full_ctx = ExecCtx::new();
        let mut cache = DeltaCache::new();
        let mut rng = Rng::new(99);
        let mut window = small_input(31);
        let mut hits = 0usize;
        for step in 0..8 {
            let (logits, outcome) = plan.execute_delta(&mut ctx, &mut cache, &window, 0.35);
            let got = logits.to_vec();
            assert_eq!(got, plan.execute(&mut full_ctx, &window).to_vec(), "step {step}");
            if step == 0 {
                assert_eq!(outcome, DeltaOutcome::Full(FullReason::ColdCache));
            }
            if outcome.is_delta() {
                hits += 1;
                assert!(outcome.dirty_frac() <= 0.35 + 1e-9);
                assert!(outcome.recomputed_frac() <= 1.0);
            }
            window = perturb_input(&mut rng, &window, 0.02);
        }
        assert!(hits >= 4, "expected mostly delta hits on 2% perturbations, got {hits}");
    }

    #[test]
    fn delta_fallback_reasons_are_reported() {
        let qnet = tiny_qnet(13);
        let plan = ExecPlan::compile(&qnet);
        let mut ctx = ExecCtx::new();
        let mut cache = DeltaCache::new();
        let mut rng = Rng::new(5);
        let base = small_input(41);
        let (_, o) = plan.execute_delta(&mut ctx, &mut cache, &base, 0.35);
        assert_eq!(o, DeltaOutcome::Full(FullReason::ColdCache));
        // Geometry change: kernels derive geometry from the map, so a
        // different resolution executes fine but must not be diffed.
        let off_spec: SparseMap<f32> = SparseMap::empty(20, 20, 2);
        let (_, o) = plan.execute_delta(&mut ctx, &mut cache, &off_spec, 0.35);
        assert_eq!(o, DeltaOutcome::Full(FullReason::Geometry));
        // Back on spec (geometry differs from the cached 20×20 again).
        let (_, o) = plan.execute_delta(&mut ctx, &mut cache, &base, 0.35);
        assert_eq!(o, DeltaOutcome::Full(FullReason::Geometry));
        // Identical window at max_frac 0: zero dirty sites, zero recompute.
        let (logits, o) = plan.execute_delta(&mut ctx, &mut cache, &base, 0.0);
        let same = logits.to_vec();
        match o {
            DeltaOutcome::Delta { dirty: 0, recomputed: 0, .. } => {}
            other => panic!("expected a zero-site delta hit, got {other:?}"),
        }
        assert_eq!(same, plan.execute(&mut ExecCtx::new(), &base).to_vec());
        // Any change at max_frac 0 falls back over-threshold.
        let changed = perturb_input(&mut rng, &base, 0.05);
        let (_, o) = plan.execute_delta(&mut ctx, &mut cache, &changed, 0.0);
        assert_eq!(o, DeltaOutcome::Full(FullReason::OverThreshold));
        // An invalidated cache cold-starts.
        cache.invalidate();
        let (_, o) = plan.execute_delta(&mut ctx, &mut cache, &changed, 0.35);
        assert_eq!(o, DeltaOutcome::Full(FullReason::ColdCache));
    }

    #[test]
    fn delta_cache_is_plan_stamped() {
        // A cache warmed by plan A must not feed stale activations to plan
        // B: the fingerprint stamp forces a cold-cache full pass instead.
        let qa = ExecPlan::compile(&tiny_qnet(3));
        let qb = ExecPlan::compile(&tiny_qnet(4));
        assert_ne!(qa.fingerprint, qb.fingerprint);
        let mut ctx = ExecCtx::new();
        let mut cache = DeltaCache::new();
        let input = small_input(9);
        qa.execute_delta(&mut ctx, &mut cache, &input, 0.35);
        let (logits, o) = qb.execute_delta(&mut ctx, &mut cache, &input, 0.35);
        assert_eq!(o, DeltaOutcome::Full(FullReason::ColdCache));
        assert_eq!(logits.to_vec(), qb.execute(&mut ExecCtx::new(), &input).to_vec());
    }
}
