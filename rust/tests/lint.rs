//! Fixture tests for the in-tree linter (`esda lint`), one cluster per
//! rule — each proves the violation is caught, the clean form passes,
//! and `lint:allow` suppression works (with the reason mandatory) —
//! plus the self-check: the shipped tree must lint clean, so `esda
//! lint` in CI is a real gate and not an aspiration.

use esda::lint::{collect_files, lint_sources, SourceFile};
use std::path::PathBuf;

/// Lint a single in-memory file (no README → drift-flags is skipped).
fn lint_one(rel: &str, text: &str) -> Vec<String> {
    lint_files(&[(rel, text)], None)
}

fn lint_files(files: &[(&str, &str)], readme: Option<&str>) -> Vec<String> {
    let files: Vec<SourceFile> = files
        .iter()
        .map(|(rel, text)| SourceFile { rel_path: rel.to_string(), text: text.to_string() })
        .collect();
    lint_sources(&files, readme).iter().map(|f| f.render()).collect()
}

fn assert_clean(findings: &[String]) {
    assert!(findings.is_empty(), "expected no findings, got:\n{}", findings.join("\n"));
}

// ---------------------------------------------------------------- panic

#[test]
fn panic_rule_catches_unwrap_on_the_serving_path() {
    let found = lint_one("coordinator/fixture.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n");
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].starts_with("coordinator/fixture.rs:1: panic:"), "{}", found[0]);
    assert!(found[0].contains(".unwrap()"), "{}", found[0]);
}

#[test]
fn panic_rule_catches_every_token_and_reports_each_line() {
    let text = "fn f() {\n    todo!()\n}\nfn g() {\n    unreachable!()\n}\n";
    let found = lint_one("sparse/fixture.rs", text);
    assert_eq!(found.len(), 2, "{found:?}");
    assert!(found[0].contains(":2: panic:"), "{}", found[0]);
    assert!(found[1].contains(":5: panic:"), "{}", found[1]);
}

#[test]
fn panic_rule_skips_unscoped_files_clean_files_and_test_code() {
    // Same violation, but outside the panic scope.
    assert_clean(&lint_one("util/fixture.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n"));
    // Clean scoped file.
    assert_clean(&lint_one("events/fixture.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n"));
    // Violations inside #[cfg(test)] / #[test] items are exempt.
    let text = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { panic!(\"boom\") }\n}\n";
    assert_clean(&lint_one("events/fixture.rs", text));
}

#[test]
fn panic_rule_allows_the_lock_poisoning_idiom_by_pattern() {
    // events/ is panic-scoped but outside the coordinator/ lock-order
    // scope, so the idiom can be tested without declaring lock ranks.
    assert_clean(&lint_one("events/fixture.rs", "fn f(m: &M) { m.lock().unwrap(); }\n"));
    // ... including rustfmt-split chains.
    let split =
        "fn f(s: &S) {\n    s.inner\n        .lock()\n        .unwrap()\n        .push(1);\n}\n";
    assert_clean(&lint_one("events/fixture.rs", split));
    // But not arbitrary unwraps that merely mention lock elsewhere.
    let found = lint_one("coordinator/fixture.rs", "fn f(x: Option<u8>) { x.unwrap(); }\n");
    assert_eq!(found.len(), 1, "{found:?}");
}

#[test]
fn allow_with_reason_suppresses_on_same_or_preceding_comment_line() {
    let same = "fn f(x: Option<u8>) { x.unwrap(); } // lint:allow(panic): guarded above\n";
    assert_clean(&lint_one("coordinator/fixture.rs", same));
    let above = "fn f(x: Option<u8>) {\n    // lint:allow(panic): guarded by the caller\n    \
                 x.unwrap();\n}\n";
    assert_clean(&lint_one("coordinator/fixture.rs", above));
}

#[test]
fn reasonless_allow_is_itself_a_finding_and_does_not_suppress_silently() {
    let text = "fn f(x: Option<u8>) {\n    // lint:allow(panic)\n    x.unwrap();\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("without a reason"), "{}", found[0]);
    assert!(found[0].contains(":2:"), "flagged at the marker line: {}", found[0]);
}

#[test]
fn allow_for_a_different_rule_does_not_suppress() {
    let text = "fn f(x: Option<u8>) {\n    // lint:allow(cast): wrong rule\n    x.unwrap();\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("panic"), "{}", found[0]);
}

#[test]
fn tokens_inside_strings_and_comments_are_not_violations() {
    let text = "fn f() -> &'static str {\n    // a comment saying panic! and .unwrap()\n    \
                \"panic! .unwrap() todo!\"\n}\n";
    assert_clean(&lint_one("coordinator/fixture.rs", text));
}

// ------------------------------------------------------------ hot-alloc

#[test]
fn hot_alloc_catches_allocation_inside_a_marked_region() {
    let text = "// lint: hot-path\nfn k(v: &[u8]) -> Vec<u8> {\n    v.to_vec()\n}\n\
                // lint: hot-path end\n";
    let found = lint_one("anywhere.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":3: hot-alloc:"), "{}", found[0]);
    assert!(found[0].contains(".to_vec()"), "{}", found[0]);
}

#[test]
fn hot_alloc_ignores_allocation_outside_regions() {
    let text = "fn setup() -> Vec<u8> {\n    vec![0; 8]\n}\n// lint: hot-path\n\
                fn k(acc: &mut [u8]) { acc[0] = 1; }\n// lint: hot-path end\n";
    assert_clean(&lint_one("anywhere.rs", text));
}

#[test]
fn hot_alloc_flags_unbalanced_markers() {
    let unclosed = lint_one("anywhere.rs", "// lint: hot-path\nfn k() {}\n");
    assert_eq!(unclosed.len(), 1, "{unclosed:?}");
    assert!(unclosed[0].contains("never closed"), "{}", unclosed[0]);
    let orphan = lint_one("anywhere.rs", "fn k() {}\n// lint: hot-path end\n");
    assert_eq!(orphan.len(), 1, "{orphan:?}");
    assert!(orphan[0].contains("without an open region"), "{}", orphan[0]);
}

#[test]
fn hot_alloc_respects_allow_annotations() {
    let text = "// lint: hot-path\nfn k() {\n    // lint:allow(hot-alloc): first call sizes \
                the arena\n    let v = Vec::new();\n    drop(v);\n}\n// lint: hot-path end\n";
    assert_clean(&lint_one("anywhere.rs", text));
}

// ----------------------------------------------------------------- cast

#[test]
fn cast_rule_catches_bare_narrowing_casts_in_wire_files_only() {
    let text = "fn f(v: u64) -> u32 { v as u32 }\n";
    let found = lint_one("events/io.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("cast: bare `as u32`"), "{}", found[0]);
    // The same text in a non-wire file is out of scope.
    assert_clean(&lint_one("events/other.rs", text));
}

#[test]
fn cast_rule_ignores_widening_and_annotated_casts() {
    assert_clean(&lint_one("coordinator/net.rs", "fn f(v: u16) -> u64 { v as u64 }\n"));
    let annotated = "fn f(v: usize) -> u16 {\n    // lint:allow(cast): v < 4 by construction\n    \
                     v as u16\n}\n";
    assert_clean(&lint_one("coordinator/net.rs", annotated));
}

// ---------------------------------------------------------------- print

#[test]
fn print_rule_bans_println_in_library_modules_only() {
    let text = "fn f() {\n    println!(\"hi\");\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("print: `println!`"), "{}", found[0]);
    assert_clean(&lint_one("main.rs", text));
    assert_clean(&lint_one("report/fixture.rs", text));
}

// -------------------------------------------------------- drift-metrics

const METRICS_FIXTURE: &str = "pub struct Metrics {\n    pub served: usize,\n    \
                               pub ghosts: usize,\n    pub rate: f64,\n}\n";

#[test]
fn drift_metrics_flags_counters_never_referenced_in_report() {
    let report = "pub fn line(m: &Metrics) -> String { m.served.to_string() }\n";
    let found = lint_files(
        &[("coordinator/metrics.rs", METRICS_FIXTURE), ("report/mod.rs", report)],
        None,
    );
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("Metrics.ghosts"), "{}", found[0]);
    assert!(!found[0].contains("rate"), "non-usize fields are not counters: {}", found[0]);
}

#[test]
fn drift_metrics_passes_when_every_counter_is_rendered_and_skips_bare_lists() {
    let report = "pub fn line(m: &Metrics) -> String {\n    \
                  format!(\"{} {}\", m.served, m.ghosts)\n}\n";
    assert_clean(&lint_files(
        &[("coordinator/metrics.rs", METRICS_FIXTURE), ("report/mod.rs", report)],
        None,
    ));
    // Linting metrics.rs alone (no report files in the set) skips the
    // rule instead of flagging everything.
    assert_clean(&lint_files(&[("coordinator/metrics.rs", METRICS_FIXTURE)], None));
}

// ---------------------------------------------------------- drift-flags

#[test]
fn drift_flags_requires_parsed_flags_to_be_documented() {
    let cli = "fn f(a: &Args) -> bool { a.has(\"verbose\") || a.has(\"mystery\") }\n";
    let readme = "Usage: pass `--verbose` for more output.\n";
    let found = lint_files(&[("main.rs", cli)], Some(readme));
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("--mystery"), "{}", found[0]);
    // With the flag documented, the set is clean.
    let full = "Usage: `--verbose`, `--mystery`.\n";
    assert_clean(&lint_files(&[("main.rs", cli)], Some(full)));
    // Without a README in reach the rule is skipped, not exploded.
    assert_clean(&lint_files(&[("main.rs", cli)], None));
}

#[test]
fn drift_flags_ignores_non_accessor_strings() {
    let cli = "fn f() -> String { String::from(\"mystery\") }\n";
    assert_clean(&lint_files(&[("main.rs", cli)], Some("no flags here\n")));
}

// ----------------------------------------------------------- module-size

/// A fixture module with `n` counted code lines (plus optional padding
/// the rule must ignore).
fn module_of(n: usize, padding: &str) -> String {
    format!("fn f() {{\n{}}}\n{padding}", "    let _x = 1;\n".repeat(n.saturating_sub(2)))
}

#[test]
fn module_size_flags_oversized_library_modules_at_line_one() {
    let found = lint_one("coordinator/fixture.rs", &module_of(901, ""));
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].starts_with("coordinator/fixture.rs:1: module-size:"), "{}", found[0]);
    assert!(found[0].contains("901"), "{}", found[0]);
    assert!(found[0].contains("900"), "{}", found[0]);
}

#[test]
fn module_size_passes_at_the_cap_and_ignores_blank_comment_and_test_lines() {
    assert_clean(&lint_one("coordinator/fixture.rs", &module_of(900, "")));
    // Blank lines and comments are not code: 900 code lines plus a sea
    // of padding still pass.
    let padding = "\n// commentary\n".repeat(300);
    assert_clean(&lint_one("coordinator/fixture.rs", &module_of(900, &padding)));
    // #[cfg(test)] items don't count toward the cap either.
    let tests =
        format!("#[cfg(test)]\nmod tests {{\n{}}}\n", "    fn t() {}\n".repeat(600));
    assert_clean(&lint_one("coordinator/fixture.rs", &module_of(890, &tests)));
    // main.rs is the binary, not a library module.
    assert_clean(&lint_one("main.rs", &module_of(1200, "")));
}

#[test]
fn module_size_respects_a_reasoned_allow_on_line_one() {
    let text = format!(
        "// lint:allow(module-size): split scheduled for the next PR\n{}",
        module_of(950, "")
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &text));
}

// ----------------------------------------------------------- allow-file

#[test]
fn allow_file_masthead_suppresses_a_rule_file_wide() {
    let text = "// lint:allow-file(panic): fail-fast demo binary\n\
                fn main() {\n    let x: Option<u8> = None;\n    x.unwrap();\n    \
                Some(1).expect(\"present\");\n}\n";
    assert_clean(&lint_one("examples/fixture.rs", text));
    assert_clean(&lint_one("benches/fixture.rs", text));
}

#[test]
fn allow_file_is_per_rule_and_reasonless_masthead_is_a_finding() {
    // A panic masthead does not blanket other rules.
    let text = "// lint:allow-file(panic): fail-fast demo binary\n\
                fn f(v: u64) -> u32 { v.try_into().unwrap() }\n";
    assert_clean(&lint_one("examples/net_serving.rs", text));
    let cast = "// lint:allow-file(panic): fail-fast demo binary\n\
                fn f(v: u64) -> u32 { v as u32 }\n";
    let found = lint_one("examples/net_serving.rs", cast);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("cast:"), "{}", found[0]);
    // Reasonless masthead: flagged at the directive, not silently obeyed.
    let bare = "// lint:allow-file(panic)\nfn main() { Some(1).unwrap(); }\n";
    let found = lint_one("examples/fixture.rs", bare);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":1: panic:"), "{}", found[0]);
    assert!(found[0].contains("without a reason"), "{}", found[0]);
}

#[test]
fn allow_file_must_sit_in_the_masthead_window() {
    // The directive lands on line 31 — one past the window — so it is
    // invisible and the violation still reports.
    let pad = "fn a() {}\n".repeat(30);
    let text =
        format!("{pad}// lint:allow-file(panic): buried too deep\nfn b() {{ Some(1).unwrap(); }}\n");
    let found = lint_one("examples/fixture.rs", &text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("panic"), "{}", found[0]);
}

#[test]
fn binaries_share_the_print_and_module_size_exemptions() {
    let text = "fn main() {\n    println!(\"hi\");\n}\n";
    assert_clean(&lint_one("examples/fixture.rs", text));
    assert_clean(&lint_one("benches/fixture.rs", text));
}

// ------------------------------------------------------------ lock-order

#[test]
fn lock_order_requires_a_rank_on_every_coordinator_lock_declaration() {
    for decl in ["q: Mutex<Vec<u8>>,", "q: RankedMutex<Vec<u8>>,", "cv: Condvar,"] {
        let text = format!("struct S {{\n    {decl}\n}}\n");
        let found = lint_one("coordinator/fixture.rs", &text);
        assert_eq!(found.len(), 1, "{decl}: {found:?}");
        assert!(found[0].contains(":2: lock-order:"), "{}", found[0]);
        assert!(found[0].contains("without a lock rank"), "{}", found[0]);
        // The same declaration outside coordinator/ is out of scope.
        assert_clean(&lint_one("util/fixture.rs", &text));
    }
    // `Condvar::` paths and `use` lines are not declarations.
    let uses = "use std::sync::{Condvar, Mutex};\nfn f() -> bool { Condvar::new; true }\n";
    assert_clean(&lint_one("coordinator/fixture.rs", uses));
}

/// Shared fixture: two ranked locks and a well-ordered taker.
const RANKED_PAIR: &str = "struct S {\n    // lint: lock-rank(10): alpha\n    \
                           alpha: Mutex<u8>,\n    // lint: lock-rank(20): beta\n    \
                           beta: Mutex<u8>,\n}\n";

#[test]
fn lock_order_accepts_rank_ascending_nesting() {
    let text = format!(
        "{RANKED_PAIR}fn f(s: &S) {{\n    let alpha = s.alpha.lock().unwrap();\n    \
         let beta = s.beta.lock().unwrap();\n    drop(beta);\n    drop(alpha);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &text));
}

#[test]
fn lock_order_flags_a_rank_inversion_at_the_acquisition_site() {
    let text = format!(
        "{RANKED_PAIR}fn g(s: &S) {{\n    let beta = s.beta.lock().unwrap();\n    \
         let alpha = s.alpha.lock().unwrap();\n}}\n"
    );
    let found = lint_one("coordinator/fixture.rs", &text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":9: lock-order:"), "{}", found[0]);
    assert!(found[0].contains("inverts the lock order"), "{}", found[0]);
    assert!(found[0].contains("`alpha` (rank 10)"), "{}", found[0]);
    assert!(found[0].contains("`beta` (rank 20)"), "{}", found[0]);
}

#[test]
fn lock_order_tracks_drops_so_reacquisition_is_not_an_inversion() {
    let text = format!(
        "{RANKED_PAIR}fn f(s: &S) {{\n    let beta = s.beta.lock().unwrap();\n    \
         drop(beta);\n    let alpha = s.alpha.lock().unwrap();\n    drop(alpha);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &text));
}

#[test]
fn lock_order_flags_an_unranked_receiver_and_conflicting_redeclarations() {
    let text = "fn f(s: &S) {\n    let g = s.mystery.lock().unwrap();\n    drop(g);\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("`mystery`, which has no declared rank"), "{}", found[0]);
    // One ident, two ranks: the registry is tree-wide, so this is a lie.
    let redecl = "struct A {\n    // lint: lock-rank(10): q\n    q: Mutex<u8>,\n}\n\
                  struct B {\n    // lint: lock-rank(20): q\n    q: Mutex<u8>,\n}\n";
    let found = lint_one("coordinator/fixture.rs", redecl);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("re-declared at rank 20"), "{}", found[0]);
}

#[test]
fn lock_order_flags_a_malformed_directive_and_still_demands_a_rank() {
    let text = "struct S {\n    // lint: lock-rank(ten): q\n    q: Mutex<u8>,\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 2, "{found:?}");
    assert!(found[0].contains("malformed lock-rank directive"), "{}", found[0]);
    assert!(found[1].contains("without a lock rank"), "{}", found[1]);
}

// ------------------------------------------------------------- lock-span

#[test]
fn lock_span_flags_a_bound_guard_held_across_a_blocking_call() {
    let text = format!(
        "{RANKED_PAIR}fn f(s: &S, rx: &R) {{\n    let alpha = s.alpha.lock().unwrap();\n    \
         let x = rx.recv();\n    drop(alpha);\n    drop(x);\n}}\n"
    );
    let found = lint_one("coordinator/fixture.rs", &text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":9: lock-span:"), "{}", found[0]);
    assert!(found[0].contains("held across blocking `.recv(..)`"), "{}", found[0]);
}

#[test]
fn lock_span_passes_when_the_guard_is_dropped_or_merely_a_temporary() {
    // Dropped before the blocking call.
    let dropped = format!(
        "{RANKED_PAIR}fn f(s: &S, rx: &R) {{\n    let alpha = s.alpha.lock().unwrap();\n    \
         drop(alpha);\n    let x = rx.recv();\n    drop(x);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &dropped));
    // A statement-temporary guard dies at its `;` — not a held span.
    let temp = format!(
        "{RANKED_PAIR}fn f(s: &S, rx: &R) {{\n    *s.alpha.lock().unwrap() += 1;\n    \
         let x = rx.recv();\n    drop(x);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &temp));
}

#[test]
fn lock_span_respects_a_reasoned_allow_at_the_blocking_site() {
    let text = format!(
        "{RANKED_PAIR}fn f(s: &S, cv: &C) {{\n    let alpha = s.alpha.lock().unwrap();\n    \
         // lint:allow(lock-span): the wait releases the guard while parked\n    \
         let alpha = cv.wait_timeout(alpha, D).0;\n    drop(alpha);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &text));
}

#[test]
fn lock_span_guard_dies_with_its_enclosing_block() {
    let text = format!(
        "{RANKED_PAIR}fn f(s: &S, rx: &R) {{\n    {{\n        \
         let alpha = s.alpha.lock().unwrap();\n    }}\n    let x = rx.recv();\n    \
         drop(x);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &text));
}

// ------------------------------------------------------------ atomic-rmw

/// Shared fixture: one seqcst-contracted atomic counter field.
const ATOMIC_FIELD: &str = "struct S {\n    // lint: atomic(seqcst): scheduling truth\n    \
                            n: AtomicUsize,\n}\n";

#[test]
fn atomic_rmw_flags_load_then_store_in_one_function() {
    let text = format!(
        "{ATOMIC_FIELD}fn f(s: &S) {{\n    let v = s.n.load(Ordering::SeqCst);\n    \
         s.n.store(v + 1, Ordering::SeqCst);\n}}\n"
    );
    let found = lint_one("coordinator/fixture.rs", &text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":7: atomic-rmw:"), "{}", found[0]);
    assert!(found[0].contains("loaded (line 6)"), "{}", found[0]);
    assert!(found[0].contains("lost-update window"), "{}", found[0]);
}

#[test]
fn atomic_rmw_passes_fetch_ops_and_cross_function_load_store() {
    let rmw =
        format!("{ATOMIC_FIELD}fn f(s: &S) {{\n    s.n.fetch_add(1, Ordering::SeqCst);\n}}\n");
    assert_clean(&lint_one("coordinator/fixture.rs", &rmw));
    // A load in one function and a store in another is not a window.
    let split = format!(
        "{ATOMIC_FIELD}fn observe(s: &S) -> usize {{\n    s.n.load(Ordering::SeqCst)\n}}\n\
         fn reset(s: &S) {{\n    s.n.store(0, Ordering::SeqCst);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &split));
}

// ------------------------------------------------------- atomic-ordering

#[test]
fn atomic_ordering_requires_a_contract_on_every_declaration() {
    let found = lint_one("coordinator/fixture.rs", "struct S {\n    n: AtomicUsize,\n}\n");
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains(":2: atomic-ordering:"), "{}", found[0]);
    assert!(found[0].contains("without an ordering contract"), "{}", found[0]);
    // `AtomicUsize::` paths don't declare anything.
    assert_clean(&lint_one(
        "coordinator/fixture.rs",
        "fn f() -> bool {\n    AtomicUsize::new(0);\n    true\n}\n",
    ));
}

#[test]
fn atomic_ordering_checks_every_use_against_the_contract() {
    let ok = format!(
        "{ATOMIC_FIELD}fn f(\n    s: &S,\n    // lint: atomic(relaxed): shutdown latch\n    \
         stop: &AtomicBool,\n) {{\n    s.n.fetch_add(1, Ordering::SeqCst);\n    \
         stop.load(Ordering::Relaxed);\n}}\n"
    );
    assert_clean(&lint_one("coordinator/fixture.rs", &ok));
    let drifted = format!(
        "{ATOMIC_FIELD}fn f(s: &S) {{\n    s.n.fetch_add(1, Ordering::Relaxed);\n}}\n"
    );
    let found = lint_one("coordinator/fixture.rs", &drifted);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("declared seqcst but used with `Relaxed`"), "{}", found[0]);
}

#[test]
fn atomic_ordering_flags_contractless_receivers_and_conflicting_modes() {
    let text = "fn f(x: &X) {\n    x.flag.load(Ordering::SeqCst);\n}\n";
    let found = lint_one("coordinator/fixture.rs", text);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("`flag`, which has no declared contract"), "{}", found[0]);
    let redecl = "struct A {\n    // lint: atomic(seqcst): truth\n    n: AtomicUsize,\n}\n\
                  struct B {\n    // lint: atomic(relaxed): tally\n    n: AtomicUsize,\n}\n";
    let found = lint_one("coordinator/fixture.rs", redecl);
    assert_eq!(found.len(), 1, "{found:?}");
    assert!(found[0].contains("re-declared relaxed"), "{}", found[0]);
}

#[test]
fn concurrency_tokens_inside_strings_and_comments_are_inert() {
    let text = "fn f() -> &'static str {\n    // prose: Mutex<u8>, AtomicUsize, .lock()\n    \
                \"Mutex<AtomicUsize> .lock() .recv( Ordering::SeqCst\"\n}\n";
    assert_clean(&lint_one("coordinator/fixture.rs", text));
}

// ------------------------------------------------------------ self-check

/// The shipped tree lints clean: every genuine violation is fixed and
/// every intentional site is annotated, so the CI `esda lint` gate is
/// armed at zero. If this fails, run `cargo run -- lint --fix-plan`.
/// The walk matches the CI invocation: the library tree plus the
/// example and bench binaries.
#[test]
fn shipped_tree_is_lint_clean() {
    let roots = vec![
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src")),
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../examples")),
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/benches")),
    ];
    for r in &roots {
        assert!(r.is_dir(), "missing lint root {}", r.display());
    }
    let files = collect_files(&roots).expect("walk the shipped tree");
    assert!(files.len() > 35, "walk found only {} file(s)", files.len());
    let readme = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md"))
        .expect("README.md at the repo root");
    let findings = lint_sources(&files, Some(&readme));
    let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
    assert!(rendered.is_empty(), "shipped tree has lint findings:\n{}", rendered.join("\n"));
}
