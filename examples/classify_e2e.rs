// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! End-to-end driver (the EXPERIMENTS.md §E2E run): load the
//! python-trained artifact, classify the full synthetic test set through
//! all three execution paths, and report accuracy + latency — proving the
//! layers compose:
//!
//!   events → histogram → [rust functional f32]  (oracle)
//!                      → [PJRT dense engine]    (AOT HLO with Pallas inside)
//!                      → [int8 cycle simulator] (the paper's hardware)
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example classify_e2e [-- --dataset n_mnist]

use esda::arch::{simulate_inference, HwConfig};
use esda::events::io::read_dataset;
use esda::events::repr::histogram2_norm;
use esda::hwopt::{allocate, power::CLOCK_HZ, Budget};
use esda::model::exec::{argmax, forward_f32};
use esda::model::quant::quantize_network;
use esda::model::weights::load_float_weights;
use esda::model::NetworkSpec;
use esda::runtime::{artifact_available, artifacts_dir, Engine};
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::stats::{bench, fmt_secs};

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]).unwrap();
    let ds = args.get_or("dataset", "n_mnist").to_string();
    let stem = format!("compact_{ds}");
    if !artifact_available(&stem) {
        eprintln!("artifacts/{stem}.hlo.txt missing — run `make artifacts` first");
        std::process::exit(1);
    }
    if !esda::runtime::pjrt_enabled() {
        eprintln!(
            "built without the `pjrt` feature — add the vendored `xla` dependency in \
             rust/Cargo.toml (see its comment) and rebuild with --features pjrt"
        );
        std::process::exit(1);
    }
    let dir = artifacts_dir();

    // Trained weights + spec.
    let meta = esda::util::json::parse(
        &std::fs::read_to_string(dir.join(format!("{stem}.meta.json"))).unwrap(),
    )
    .unwrap();
    let (w, h) = (
        meta.get("w").unwrap().as_usize().unwrap(),
        meta.get("h").unwrap().as_usize().unwrap(),
    );
    let n_classes = meta.get("n_classes").unwrap().as_usize().unwrap();
    let spec = NetworkSpec::compact("compact", w, h, n_classes);
    let fw = load_float_weights(&dir.join(format!("{stem}_weights.esdw")), &spec).unwrap();
    println!(
        "model: {} ({} params), python-reported test acc {:.3}",
        stem,
        spec.param_count(),
        meta.get("test_acc").and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
    );

    // Test set (rust-generated, identical to what python trained on).
    let (dw, dh, samples) = read_dataset(&dir.join(format!("data/{ds}_test.esda"))).unwrap();
    assert_eq!((dw, dh), (w, h));
    let inputs: Vec<(usize, SparseMap<f32>)> = samples
        .iter()
        .map(|s| (s.label as usize, histogram2_norm(&s.events, w, h, 8.0)))
        .collect();
    println!("test set: {} samples", inputs.len());

    // Quantize + allocate hardware.
    let calib: Vec<_> = inputs.iter().take(8).map(|(_, m)| m.clone()).collect();
    let qnet = quantize_network(&spec, &fw, &calib);
    let bitmaps: Vec<_> = calib.iter().map(|m| m.bitmap()).collect();
    let stats = esda::hwopt::collect_stats(&spec, &bitmaps);
    let alloc = allocate(&spec, &stats, &Budget::zcu102()).expect("fits ZCU102");
    let cfg = HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };

    // PJRT engine.
    let engine = Engine::load(&dir.join(format!("{stem}.hlo.txt"))).unwrap();

    // Classify through all three paths.
    let (mut acc_f32, mut acc_pjrt, mut acc_sim) = (0usize, 0usize, 0usize);
    let mut sim_cycles: Vec<f64> = Vec::new();
    let mut disagreements = 0usize;
    for (label, input) in &inputs {
        let p_f32 = argmax(&forward_f32(&spec, &fw, input));
        let p_pjrt = argmax(&engine.infer_sparse(input).unwrap());
        let (logits_i8, report) = simulate_inference(&qnet, &cfg, input, 5_000_000_000).unwrap();
        let p_sim = argmax(&logits_i8);
        acc_f32 += (p_f32 == *label) as usize;
        acc_pjrt += (p_pjrt == *label) as usize;
        acc_sim += (p_sim == *label) as usize;
        sim_cycles.push(report.cycles as f64);
        if p_f32 != p_pjrt {
            disagreements += 1;
        }
    }
    let n = inputs.len() as f64;
    println!(
        "accuracy: f32 oracle {:.3} | PJRT artifact {:.3} | int8 simulator {:.3}",
        acc_f32 as f64 / n,
        acc_pjrt as f64 / n,
        acc_sim as f64 / n
    );
    println!("f32-vs-PJRT argmax disagreements: {disagreements} (must be 0)");
    assert_eq!(disagreements, 0, "AOT artifact drifted from the oracle");

    // Latency: simulated hardware vs measured PJRT wall time (batch 1).
    let mean_cycles = sim_cycles.iter().sum::<f64>() / sim_cycles.len() as f64;
    println!(
        "simulated ESDA latency: {:.3} ms/inf @187 MHz ({:.0} cycles avg) → {:.0} fps",
        mean_cycles / CLOCK_HZ * 1e3,
        mean_cycles,
        CLOCK_HZ / mean_cycles
    );
    let sample = inputs[0].1.clone();
    let s = bench(3, 10, || {
        let _ = engine.infer_sparse(&sample).unwrap();
    });
    println!(
        "PJRT dense-engine wall latency (this host): median {} / inf",
        fmt_secs(s.median())
    );
    println!("E2E OK");
}
