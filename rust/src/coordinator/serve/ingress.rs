//! Ingress stages: the **source pump** (stage 1 — pulls the
//! [`EventSource`], owns pacing and arrival timestamps, skips past
//! recoverable rejects) and the **repr builder + admission gate**
//! (stage 2 — builds the sparse histogram representation, resolves each
//! request's deadline, and enforces the tenant quotas and the ingress
//! deadline expiry before the request costs anything downstream).

use super::state::{IngressBooks, Routed, SharedCtx};
use crate::coordinator::ingest::{EventSource, SourcedRequest};
use crate::coordinator::metrics::CostModel;
use crate::events::repr::histogram2_norm;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, SyncSender};
use std::time::Instant;

/// Stage 1: the event source (synthetic camera, dataset replay, capture
/// tail, or socket) — owns pacing and arrival timestamps. A recoverable
/// [`crate::coordinator::ingest::IngestError`] is counted and skipped;
/// a fatal one records the run's first error and ends the stream.
pub(super) fn pump_source(
    mut src: Box<dyn EventSource>,
    tx: SyncSender<SourcedRequest>,
    books: &IngressBooks,
    sx: &SharedCtx<'_, '_>,
) {
    loop {
        match src.next_request() {
            Ok(Some(req)) => {
                if tx.send(req).is_err() {
                    return; // downstream hung up early
                }
            }
            Ok(None) => return, // stream complete
            Err(e) if e.is_recoverable() => {
                // A per-sample validation reject: the reader is still
                // aligned and the stream continues — count it and keep
                // pulling. One bad sample must not kill the serving run.
                books.ingest_rejects.fetch_add(1, Ordering::Relaxed);
                // Attribute it when the source knows the tenant (socket
                // packets) or when there is only one.
                let t = e.tenant().or((sx.tenants.len() == 1).then_some(0));
                if let Some(tc) = t.and_then(|t| sx.tenants.get(t)) {
                    tc.ingest_rejects.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(e) => {
                // Fatal: a latched byte-stream failure. Record it and end
                // the stream; the stages downstream drain what was
                // already admitted and exit cleanly.
                sx.first_error
                    .lock()
                    .unwrap()
                    .get_or_insert_with(|| format!("event source: {e}"));
                return;
            }
        }
    }
}

/// Stage 2: representation builder + admission control, including the
/// ingress deadline check and the per-tenant quota gate. Requests for
/// models in `capture_armed` keep their raw events alongside the built
/// representation so a shadow disagreement downstream can land them in
/// the capture file; everyone else drops the events here.
pub(super) fn repr_stage(
    rx: Receiver<SourcedRequest>,
    geometry: (usize, usize),
    clip: f32,
    slo: Option<std::time::Duration>,
    capture_armed: &[bool],
    books: &IngressBooks,
    sx: &SharedCtx<'_, '_>,
) {
    let (w, h) = geometry;
    let multi_tenant = sx.tenants.len() > 1;
    for sr in rx.iter() {
        // Clamp out-of-range tenant ids (a socket source whose tenant
        // table disagrees with the server's) to the last tenant rather
        // than panicking mid-spine; model ids get the same treatment.
        let t = sr.tenant.min(sx.tenants.len() - 1);
        let tc = &sx.tenants[t];
        let mi = sr.model.min(sx.models.len() - 1);
        let mc = &sx.models[mi];
        // The tenant's own SLO wins over the global one.
        let deadline = tc.slo.or(slo).map(|d| sr.arrival + d);
        if deadline.is_some() {
            books.deadline_offered.fetch_add(1, Ordering::Relaxed);
            tc.deadline_offered.fetch_add(1, Ordering::Relaxed);
            mc.deadline_offered.fetch_add(1, Ordering::Relaxed);
        }
        // Drop already-expired requests before paying for their
        // representation — the cheapest possible shed.
        if deadline.is_some_and(|dl| Instant::now() >= dl) {
            books.deadline_ingress.fetch_add(1, Ordering::Relaxed);
            tc.deadline_ingress.fetch_add(1, Ordering::Relaxed);
            mc.deadline_ingress.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        // Weighted fair admission: a tenant at its ingress quota is shed
        // *before* the repr is built — it can saturate only its own
        // share of the queue, never starve siblings.
        if multi_tenant && tc.in_queue.load(Ordering::SeqCst) >= tc.quota {
            books.quota_drops.fetch_add(1, Ordering::Relaxed);
            tc.dropped.fetch_add(1, Ordering::Relaxed);
            mc.dropped.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let map = histogram2_norm(&sr.events, w, h, clip);
        // Raw events survive past this point only when this model's
        // shadow capture might need them.
        let keep = capture_armed.get(mi).copied().unwrap_or(false);
        let req = Routed {
            label: sr.label,
            tenant: t,
            model: mi,
            bucket: CostModel::bucket_of(map.nnz()),
            map,
            events: keep.then_some(sr.events),
            arrival: sr.arrival,
            deadline,
            predicted_s: f64::NAN,
            stream: sr.stream,
            sticky: false,
        };
        if multi_tenant {
            tc.in_queue.fetch_add(1, Ordering::SeqCst);
        }
        match sx.ingress.push_evicting(req) {
            Ok(Some(victim)) => {
                // Drop-oldest made room: charge the eviction to the
                // victim's tenant and model, and free its quota slot.
                let vt = &sx.tenants[victim.tenant];
                vt.dropped.fetch_add(1, Ordering::Relaxed);
                sx.models[victim.model].dropped.fetch_add(1, Ordering::Relaxed);
                if multi_tenant {
                    vt.in_queue.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Ok(None) => {}
            Err(_) => break, // queue closed by an aborting worker
        }
    }
    sx.ingress.close();
}
