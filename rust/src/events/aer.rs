//! Address-Event Representation (AER) primitives.
//!
//! Each event is `[x, y, p, t]` (paper §2.1): pixel coordinate, polarity of
//! the intensity change, and a microsecond timestamp.

/// One DVS event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Timestamp in microseconds from recording start.
    pub t_us: u32,
    pub x: u16,
    pub y: u16,
    /// `true` = ON (intensity increase), `false` = OFF.
    pub polarity: bool,
}

/// Borrowed view over a time-ordered event slice with window helpers.
pub struct EventSlice<'a>(pub &'a [Event]);

impl<'a> EventSlice<'a> {
    /// Events with `t ∈ [t0, t1)`, via binary search (slice must be
    /// time-sorted).
    pub fn window(&self, t0: u32, t1: u32) -> &'a [Event] {
        let lo = self.0.partition_point(|e| e.t_us < t0);
        let hi = self.0.partition_point(|e| e.t_us < t1);
        &self.0[lo..hi]
    }

    /// Split into fixed-interval windows covering the whole recording
    /// (paper §4.1: "clips event recordings with a fixed time interval").
    pub fn fixed_windows(&self, interval_us: u32) -> Vec<&'a [Event]> {
        if self.0.is_empty() {
            return Vec::new();
        }
        let t_end = self.0.last().unwrap().t_us;
        let mut out = Vec::new();
        let mut t0 = 0u32;
        while t0 <= t_end {
            let w = self.window(t0, t0.saturating_add(interval_us));
            if !w.is_empty() {
                out.push(w);
            }
            t0 = t0.saturating_add(interval_us);
        }
        out
    }
}

/// Check events are time-sorted (non-strict: DVS readout can emit several
/// events in the same microsecond).
pub fn is_time_sorted(events: &[Event]) -> bool {
    events.windows(2).all(|w| w[0].t_us <= w[1].t_us)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u32) -> Event {
        Event { t_us: t, x: 0, y: 0, polarity: true }
    }

    #[test]
    fn window_selects_half_open_range() {
        let es = vec![ev(0), ev(10), ev(20), ev(30)];
        let s = EventSlice(&es);
        let w = s.window(10, 30);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].t_us, 10);
        assert_eq!(w[1].t_us, 20);
    }

    #[test]
    fn fixed_windows_cover_all_events() {
        let es: Vec<Event> = (0..100).map(|i| ev(i * 7)).collect();
        let s = EventSlice(&es);
        let ws = s.fixed_windows(100);
        let total: usize = ws.iter().map(|w| w.len()).sum();
        assert_eq!(total, es.len());
        for w in &ws {
            assert!(!w.is_empty());
            let span = w.last().unwrap().t_us - w.first().unwrap().t_us;
            assert!(span < 100);
        }
    }

    #[test]
    fn sorted_check() {
        assert!(is_time_sorted(&[ev(1), ev(1), ev(2)]));
        assert!(!is_time_sorted(&[ev(2), ev(1)]));
    }
}
