//! Latency/throughput metrics for the serving runtime: per-request
//! timings, admission-control accounting (drops, in-flight), per-worker
//! and per-class utilization, p50/p95/p99 percentile summaries, and the
//! [`CostModel`] the heterogeneous router predicts service times with.

use crate::util::stats::Summary;
use std::sync::Mutex;
use std::time::Instant;

/// Per-request timing record.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    /// End-to-end latency (source arrival → classified), seconds. The
    /// arrival is the instant the request was born at its
    /// [`EventSource`](super::ingest::EventSource) — for a replayed or
    /// tailed stream that is when the recording window completed, so
    /// queue backlog shows up here exactly as it would in deployment.
    pub e2e_s: f64,
    /// Accelerator-stage service time, seconds.
    pub service_s: f64,
    /// Simulated hardware cycles (simulator backend only).
    pub sim_cycles: Option<u64>,
}

/// Percentile summary of a latency sample set. Percentiles interpolate
/// between order statistics, so for any nonempty sample
/// `p50 ≤ p95 ≤ p99 ≤ max` and the report is invariant under permutation
/// of the samples (both propcheck-verified below).
#[derive(Debug, Clone, Copy)]
pub struct PercentileReport {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Default for PercentileReport {
    fn default() -> Self {
        PercentileReport {
            n: 0,
            mean: f64::NAN,
            p50: f64::NAN,
            p95: f64::NAN,
            p99: f64::NAN,
            max: f64::NAN,
        }
    }
}

impl PercentileReport {
    /// Summarize a sample set (empty ⇒ all-NaN report). Built on
    /// [`Summary`] so there is exactly one percentile implementation in
    /// the crate — the propcheck properties below exercise it too.
    pub fn from_samples(xs: &[f64]) -> PercentileReport {
        let s = Summary::from(xs);
        if s.n() == 0 {
            return PercentileReport::default();
        }
        PercentileReport {
            n: s.n(),
            mean: s.mean(),
            p50: s.percentile(50.0),
            p95: s.percentile(95.0),
            p99: s.percentile(99.0),
            max: s.max(),
        }
    }
}

/// Per-class service-time predictor for the heterogeneous router: an EWMA
/// of observed per-request service seconds, bucketed by input sparsity
/// (log2 of the map's nonzero count), plus a class-wide EWMA fallback for
/// buckets with no observation yet. "Seeded from first requests": until a
/// class has served anything, [`CostModel::predict`] returns `None` and
/// the router probes it instead of trusting a made-up number.
#[derive(Debug, Default)]
pub struct CostModel {
    state: Mutex<CostState>,
}

#[derive(Debug, Default)]
struct CostState {
    /// Class-wide EWMA over every observation (bucket fallback).
    global: Option<f64>,
    /// Per-bucket EWMAs, indexed by [`CostModel::bucket_of`].
    buckets: Vec<Option<f64>>,
}

impl CostModel {
    /// EWMA smoothing factor: heavy enough that a one-off hiccup doesn't
    /// repaint the class, light enough to track real drift within a run.
    pub const ALPHA: f64 = 0.25;

    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Event-count bucket: log2 of the input's nonzero count (empty maps
    /// share bucket 1 with single-event maps). Sparse service time scales
    /// with nnz, so log buckets give the predictor resolution where it
    /// matters without a bucket per exact count.
    pub fn bucket_of(nnz: usize) -> usize {
        (usize::BITS - nnz.max(1).leading_zeros()) as usize
    }

    /// Predicted per-request service seconds for `bucket`: the bucket EWMA
    /// when seeded, else the class-wide EWMA, else `None` (class never
    /// observed — the router must probe, not trust).
    pub fn predict(&self, bucket: usize) -> Option<f64> {
        let st = self.state.lock().unwrap();
        st.buckets.get(bucket).copied().flatten().or(st.global)
    }

    /// Fold one observed per-request service time into the model.
    pub fn observe(&self, bucket: usize, service_s: f64) {
        if !service_s.is_finite() || service_s < 0.0 {
            return;
        }
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        if st.buckets.len() <= bucket {
            st.buckets.resize(bucket + 1, None);
        }
        for slot in [&mut st.buckets[bucket], &mut st.global] {
            *slot = Some(match *slot {
                Some(v) => v + Self::ALPHA * (service_s - v),
                None => service_s,
            });
        }
    }
}

/// Per-class accounting for the heterogeneous replica pool: who served
/// what, at what batch shape, and how well the routing cost model
/// predicted reality.
#[derive(Debug, Clone)]
pub struct ClassStats {
    /// Replica-class display name (e.g. `func`, `sim`, `dense`).
    pub class: String,
    /// Worker replicas in this class.
    pub replicas: usize,
    /// Requests this class served.
    pub served: usize,
    /// Accelerator visits (micro-batches) this class made.
    pub batches: usize,
    /// Total accelerator-busy seconds across the class's replicas.
    pub busy_s: f64,
    /// Batch-size percentiles across this class's visits.
    pub batch: PercentileReport,
    /// Service-latency percentiles for requests this class served.
    pub service: PercentileReport,
    /// Mean relative routing-cost error `|predicted − actual| / actual`
    /// over requests routed with a seeded predictor (NaN when none were).
    pub cost_err: f64,
    /// Requests routed to this class before its cost model had any
    /// observation (the probe traffic that seeds the EWMA).
    pub unseeded: usize,
    /// Requests bound for this class that were shed on deadline grounds:
    /// the router predicted this (best) class could not complete them in
    /// time, or they expired in the class's queue before a worker reached
    /// them.
    pub deadline_drops: usize,
}

impl ClassStats {
    /// Mean fraction of the wall-clock interval this class's replicas
    /// spent serving.
    pub fn utilization(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 || self.replicas == 0 {
            return f64::NAN;
        }
        self.busy_s / (wall_s * self.replicas as f64)
    }
}

/// Per-worker accounting for the replicated accelerator pool.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    /// Worker replica index.
    pub worker: usize,
    /// Replica class this worker belongs to. The serving runtime always
    /// fills it (the homogeneous path uses the backend's `name()`); it is
    /// empty only on hand-built `Default` values, which the report renders
    /// as a dash.
    pub class: String,
    /// Requests this replica served.
    pub served: usize,
    /// Accelerator visits (micro-batches) this replica made;
    /// `served / batches` is its mean batch size.
    pub batches: usize,
    /// Total accelerator-busy seconds.
    pub busy_s: f64,
    /// Service-latency percentiles for this replica.
    pub service: PercentileReport,
    /// End-to-end latency percentiles for requests this replica served.
    pub e2e: PercentileReport,
    /// Batch-size percentiles across this replica's accelerator visits.
    pub batch: PercentileReport,
}

impl WorkerStats {
    /// Fraction of the wall-clock interval this replica spent serving.
    pub fn utilization(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return f64::NAN;
        }
        self.busy_s / wall_s
    }
}

/// Aggregated serving metrics.
#[derive(Debug)]
pub struct Metrics {
    pub started: Instant,
    pub timings: Vec<RequestTiming>,
    pub correct: usize,
    pub total: usize,
    /// Requests evicted by admission control (drop-oldest under saturation).
    /// (Requests stranded by an aborted run are not in any `Metrics` —
    /// they're reported via `PipelineError::in_flight` on the error path.)
    pub dropped: usize,
    /// Deadline-carrying requests that entered the system (the SLO
    /// attainment denominator; 0 when no `--slo-ms` was set).
    pub deadline_offered: usize,
    /// Requests already past their deadline at the ingress (dropped
    /// before admission — they never occupied a queue slot).
    pub deadline_ingress: usize,
    /// Requests shed at the scheduling point: the router's predictive
    /// shed (no class's predicted completion met the deadline) plus
    /// expiries at the worker pop — the routerless single-class path's
    /// scheduling point, and the post-route safety net in pools.
    pub deadline_router: usize,
    /// Served requests that completed within their deadline.
    pub deadline_met: usize,
    /// Served requests that completed *after* their deadline (they count
    /// as served, but against SLO attainment).
    pub deadline_missed: usize,
    /// Per-replica stats, one entry per pool worker (the single-
    /// accelerator `run_pipeline` facade has exactly one).
    pub per_worker: Vec<WorkerStats>,
    /// Per-class stats, one entry per replica class of the heterogeneous
    /// pool (a single entry for the homogeneous `run_server` path).
    pub per_class: Vec<ClassStats>,
    /// Size of every micro-batch any worker pulled from the ingress queue
    /// (one entry per accelerator visit, across all workers).
    pub batch_sizes: Vec<usize>,
    /// Wall-clock duration of the completed run in seconds (0 until the
    /// runtime finalizes it — see [`Metrics::wall_seconds`]).
    pub wall_s: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            timings: Vec::new(),
            correct: 0,
            total: 0,
            dropped: 0,
            deadline_offered: 0,
            deadline_ingress: 0,
            deadline_router: 0,
            deadline_met: 0,
            deadline_missed: 0,
            per_worker: Vec::new(),
            per_class: Vec::new(),
            batch_sizes: Vec::new(),
            wall_s: 0.0,
        }
    }
}

impl Metrics {
    pub fn record(&mut self, t: RequestTiming, correct: bool) {
        self.timings.push(t);
        self.total += 1;
        if correct {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.correct as f64 / self.total as f64
    }

    /// Requests offered to the system: served + queue-full drops +
    /// deadline drops (without an SLO the deadline terms are 0, so this
    /// stays served + dropped).
    pub fn offered(&self) -> usize {
        self.total + self.dropped + self.deadline_drops()
    }

    /// Fraction of offered requests shed by queue-full admission control
    /// (deadline sheds are reported separately — see
    /// [`Metrics::deadline_drops`]).
    pub fn drop_rate(&self) -> f64 {
        if self.offered() == 0 {
            return 0.0;
        }
        self.dropped as f64 / self.offered() as f64
    }

    /// Total deadline-based sheds, distinguished from queue-full drops:
    /// ingress expiries plus router/scheduling-point sheds.
    pub fn deadline_drops(&self) -> usize {
        self.deadline_ingress + self.deadline_router
    }

    /// SLO attainment: the fraction of deadline-carrying requests that
    /// were served within their deadline. Everything else — ingress
    /// expiry, router shed, queue-full drop, served-but-late — counts
    /// against it. `None` when no request carried a deadline (no SLO
    /// configured).
    pub fn slo_attainment(&self) -> Option<f64> {
        if self.deadline_offered == 0 {
            return None;
        }
        Some(self.deadline_met as f64 / self.deadline_offered as f64)
    }

    pub fn e2e_summary(&self) -> Summary {
        Summary::from(&self.timings.iter().map(|t| t.e2e_s).collect::<Vec<_>>())
    }

    pub fn service_summary(&self) -> Summary {
        Summary::from(&self.timings.iter().map(|t| t.service_s).collect::<Vec<_>>())
    }

    /// Aggregated end-to-end latency percentiles.
    pub fn e2e_percentiles(&self) -> PercentileReport {
        PercentileReport::from_samples(&self.timings.iter().map(|t| t.e2e_s).collect::<Vec<_>>())
    }

    /// Aggregated service-latency percentiles.
    pub fn service_percentiles(&self) -> PercentileReport {
        PercentileReport::from_samples(
            &self.timings.iter().map(|t| t.service_s).collect::<Vec<_>>(),
        )
    }

    /// Wall-clock duration of the run: the finalized duration recorded by
    /// the serving runtime, or time-since-start while still in flight —
    /// so utilization/throughput don't dilute when a result is rendered
    /// long after the run completed.
    pub fn wall_seconds(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.wall_s
        } else {
            self.started.elapsed().as_secs_f64()
        }
    }

    /// Wall-clock throughput (requests/s).
    pub fn throughput(&self) -> f64 {
        let dt = self.wall_seconds();
        if dt <= 0.0 {
            return f64::NAN;
        }
        self.total as f64 / dt
    }

    /// Batch-size distribution across all accelerator visits (empty ⇒
    /// all-NaN report, as with the latency percentiles).
    pub fn batch_percentiles(&self) -> PercentileReport {
        PercentileReport::from_samples(
            &self.batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
    }

    /// Mean requests per accelerator visit (NaN with no visits). 1.0 means
    /// micro-batching never coalesced anything.
    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return f64::NAN;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Mean simulated hardware latency in ms at `clock_hz`, when available.
    pub fn mean_sim_latency_ms(&self, clock_hz: f64) -> Option<f64> {
        let cycles: Vec<f64> = self
            .timings
            .iter()
            .filter_map(|t| t.sim_cycles.map(|c| c as f64))
            .collect();
        if cycles.is_empty() {
            return None;
        }
        Some(cycles.iter().sum::<f64>() / cycles.len() as f64 / clock_hz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.010, service_s: 0.002, sim_cycles: Some(1000) }, true);
        m.record(RequestTiming { e2e_s: 0.020, service_s: 0.004, sim_cycles: Some(3000) }, false);
        assert_eq!(m.total, 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert!((m.e2e_summary().mean() - 0.015).abs() < 1e-9);
        let lat = m.mean_sim_latency_ms(1e6).unwrap();
        assert!((lat - 2.0).abs() < 1e-9); // 2000 cycles avg @1MHz = 2ms
    }

    #[test]
    fn drop_accounting() {
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.01, service_s: 0.01, sim_cycles: None }, true);
        m.dropped = 3;
        assert_eq!(m.offered(), 4);
        assert!((m.drop_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_report_known_values() {
        let p = PercentileReport::from_samples(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(p.n, 4);
        assert!((p.mean - 2.5).abs() < 1e-12);
        assert!((p.p50 - 2.5).abs() < 1e-12);
        assert!((p.max - 4.0).abs() < 1e-12);
        // Empty set is explicit about having no data.
        let e = PercentileReport::from_samples(&[]);
        assert_eq!(e.n, 0);
        assert!(e.p50.is_nan() && e.max.is_nan());
    }

    /// Property: percentiles are monotone in q and bounded by the extremes.
    #[test]
    fn percentile_ordering_property() {
        check("p50 ≤ p95 ≤ p99 ≤ max", 256, |g: &mut Gen| {
            let n = g.usize(1, 200);
            let xs: Vec<f64> = (0..n).map(|_| g.f64() * 10.0 - 5.0).collect();
            let p = PercentileReport::from_samples(&xs);
            assert!(p.p50 <= p.p95, "p50 {} > p95 {}", p.p50, p.p95);
            assert!(p.p95 <= p.p99, "p95 {} > p99 {}", p.p95, p.p99);
            assert!(p.p99 <= p.max, "p99 {} > max {}", p.p99, p.max);
            let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(p.p50 >= lo && p.max <= hi);
            assert!(p.mean >= lo - 1e-12 && p.mean <= hi + 1e-12);
        });
    }

    /// Property: the report depends only on the sample multiset, not order.
    #[test]
    fn percentile_permutation_invariance() {
        check("percentiles are permutation-invariant", 128, |g: &mut Gen| {
            let n = g.usize(1, 64);
            let mut xs: Vec<f64> = (0..n).map(|_| g.f64() * 100.0).collect();
            let p1 = PercentileReport::from_samples(&xs);
            // Fisher–Yates shuffle driven by the property's generator.
            for i in (1..xs.len()).rev() {
                let j = g.usize(0, i);
                xs.swap(i, j);
            }
            let p2 = PercentileReport::from_samples(&xs);
            // Same sorted array ⇒ bitwise-identical outputs.
            for (a, b) in [(p1.p50, p2.p50), (p1.p95, p2.p95), (p1.p99, p2.p99), (p1.max, p2.max)]
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        });
    }

    #[test]
    fn batch_distribution() {
        let mut m = Metrics::default();
        assert!(m.mean_batch().is_nan());
        assert_eq!(m.batch_percentiles().n, 0);
        m.batch_sizes.extend_from_slice(&[1, 4, 4, 7]);
        assert!((m.mean_batch() - 4.0).abs() < 1e-12);
        let p = m.batch_percentiles();
        assert_eq!(p.n, 4);
        assert!((p.max - 7.0).abs() < 1e-12);
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.max);
    }

    #[test]
    fn worker_utilization() {
        let w = WorkerStats { worker: 0, served: 10, busy_s: 0.5, ..Default::default() };
        assert!((w.utilization(1.0) - 0.5).abs() < 1e-12);
        assert!(w.utilization(0.0).is_nan());
    }

    #[test]
    fn class_utilization_divides_by_replicas() {
        let c = ClassStats {
            class: "func".into(),
            replicas: 2,
            served: 8,
            batches: 4,
            busy_s: 1.0,
            batch: PercentileReport::default(),
            service: PercentileReport::default(),
            cost_err: f64::NAN,
            unseeded: 0,
            deadline_drops: 0,
        };
        assert!((c.utilization(1.0) - 0.5).abs() < 1e-12);
        assert!(c.utilization(0.0).is_nan());
    }

    /// Deadline books: attainment over every deadline-carrying request,
    /// deadline drops distinct from queue-full drops, and `None` when no
    /// SLO was configured.
    #[test]
    fn slo_attainment_accounting() {
        let mut m = Metrics::default();
        assert_eq!(m.slo_attainment(), None, "no SLO ⇒ no attainment figure");
        assert_eq!(m.deadline_drops(), 0);
        // 10 deadline-carrying requests offered: 6 met, 1 served late,
        // 1 expired at ingress, 1 shed at the router, 1 queue-dropped.
        m.deadline_offered = 10;
        m.deadline_met = 6;
        m.deadline_missed = 1;
        m.deadline_ingress = 1;
        m.deadline_router = 1;
        m.dropped = 1;
        m.total = 7; // 6 met + 1 late
        assert_eq!(m.deadline_drops(), 2);
        assert_eq!(m.offered(), 10, "served + queue drops + deadline drops");
        assert!((m.slo_attainment().unwrap() - 0.6).abs() < 1e-12);
        assert!((m.drop_rate() - 0.1).abs() < 1e-12, "queue drops only");
    }

    #[test]
    fn cost_model_buckets_by_log2_nnz() {
        assert_eq!(CostModel::bucket_of(0), 1);
        assert_eq!(CostModel::bucket_of(1), 1);
        assert_eq!(CostModel::bucket_of(2), 2);
        assert_eq!(CostModel::bucket_of(3), 2);
        assert_eq!(CostModel::bucket_of(1024), 11);
        assert!(CostModel::bucket_of(usize::MAX) as u32 <= usize::BITS);
    }

    /// Unseeded ⇒ `None`; a bucket observation seeds that bucket; other
    /// buckets fall back to the class-wide EWMA; observations move the
    /// estimate toward recent reality.
    #[test]
    fn cost_model_seeds_and_tracks() {
        let m = CostModel::new();
        assert_eq!(m.predict(3), None, "never-observed class must not invent a cost");
        m.observe(3, 0.010);
        assert!((m.predict(3).unwrap() - 0.010).abs() < 1e-12);
        // A different bucket falls back to the class-wide estimate.
        assert!((m.predict(7).unwrap() - 0.010).abs() < 1e-12);
        // EWMA moves toward a faster observation but doesn't jump to it.
        m.observe(3, 0.002);
        let p = m.predict(3).unwrap();
        assert!(p < 0.010 && p > 0.002, "EWMA out of range: {p}");
        // Garbage observations are ignored.
        m.observe(3, f64::NAN);
        m.observe(3, -1.0);
        assert!((m.predict(3).unwrap() - p).abs() < 1e-15);
    }
}
