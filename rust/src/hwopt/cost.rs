//! The Eqn. 5 cost model: per-module average latency (cycles), BRAM, and
//! DSP as a function of the layer shape, the dataset sparsity statistics,
//! and the parallel factor, plus FF/LUT regressions.
//!
//! Depthwise 3×3 example from the paper:
//! ```text
//! lat  = (H·W·S_s) · (9·S_k) · (C/PF)
//! bram = ceil((B·9·C)/16K/PF) · PF
//! dsp  = PF
//! ```
//! Generalized per module below; `B` = 8-bit weights; one BRAM = 16 Kb, as
//! in the paper. FF/LUT use per-module base + per-PF slopes chosen to land
//! in the Table 1 range (regression constants, documented in DESIGN.md §8).

use super::stats::LayerStats;
use crate::model::graph::{NetworkSpec, Op};

/// Weight bitwidth (the paper deploys 8-bit models).
pub const WEIGHT_BITS: usize = 8;
/// BRAM capacity used by the paper's model (16 Kb).
pub const BRAM_BITS: usize = 16 * 1024;

/// Cost of one op at one PF.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// Average cycles to process one input sample (Eqn. 5 lat).
    pub latency: f64,
    pub dsp: usize,
    pub bram: usize,
    pub ff: usize,
    pub lut: usize,
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Weight-buffer BRAM: the constant buffer is partitioned `PF` ways to feed
/// the MAC array (paper: `ceil(B·9·C/16K/PF)·PF`).
fn weight_bram(n_weights: usize, pf: usize) -> usize {
    if n_weights == 0 {
        return 0;
    }
    ceil_div(n_weights * WEIGHT_BITS, BRAM_BITS * pf) * pf
}

/// SLB row-buffer BRAM: k rows of W positions × C channels × 8 b (dual
/// buffered), plus the token FIFO (negligible next to the rows).
fn slb_bram(k: usize, w: usize, c: usize) -> usize {
    ceil_div(k * w * c * 8 * 2, BRAM_BITS).max(1)
}

/// Cost of `op` with stats `st` at parallel factor `pf`.
/// `(w, h)` is the op's input resolution.
pub fn op_cost(op: &Op, st: &LayerStats, pf: usize, w: usize, _h: usize) -> OpCost {
    let pf = pf.max(1);
    match *op {
        Op::Conv1x1 { cin, cout, .. } => OpCost {
            latency: st.tokens * (ceil_div(cin * cout, pf) as f64),
            dsp: pf,
            bram: weight_bram(cin * cout, pf),
            ff: 600 + 18 * pf,
            lut: 900 + 26 * pf,
        },
        Op::DwConv { k, c, .. } => OpCost {
            // (H·W·S_s) · (k²·S_k) · ceil(C/PF)  [+ SLB]
            latency: st.tokens * ((k * k) as f64 * st.s_k) * (ceil_div(c, pf) as f64),
            dsp: pf,
            bram: weight_bram(k * k * c, pf) + slb_bram(k, w, c),
            ff: 1100 + 22 * pf,
            lut: 1600 + 30 * pf,
        },
        Op::ConvKxK { k, cin, cout, .. } => OpCost {
            latency: st.tokens * ((k * k) as f64 * st.s_k) * (ceil_div(cin * cout, pf) as f64),
            dsp: pf,
            bram: weight_bram(k * k * cin * cout, pf) + slb_bram(k, w, cin),
            ff: 1100 + 22 * pf,
            lut: 1600 + 30 * pf,
        },
        Op::ResFork => OpCost { latency: st.tokens, dsp: 0, bram: 0, ff: 150, lut: 220 },
        Op::ResAdd => OpCost {
            // Shortcut FIFO BRAM: buffers tokens+features while the branch
            // computes; sized at ~4k rows of C bytes in the builder.
            latency: st.tokens,
            dsp: 0,
            bram: 2,
            ff: 250,
            lut: 380,
        },
        Op::GlobalPool { c } => OpCost {
            latency: st.tokens + c as f64,
            dsp: 0,
            bram: 1,
            ff: 300,
            lut: 420,
        },
        Op::Fc { cin, cout } => OpCost {
            latency: ceil_div(cin * cout, pf) as f64,
            dsp: pf,
            bram: weight_bram(cin * cout, pf),
            ff: 500 + 18 * pf,
            lut: 700 + 24 * pf,
        },
    }
}

/// Cost every op of `spec` at the given PFs with the given stats.
pub fn op_costs(spec: &NetworkSpec, stats: &[LayerStats], pfs: &[usize]) -> Vec<OpCost> {
    let ops = spec.ops();
    let res = spec.op_resolutions();
    assert_eq!(ops.len(), stats.len());
    assert_eq!(ops.len(), pfs.len());
    ops.iter()
        .enumerate()
        .map(|(i, op)| op_cost(op, &stats[i], pfs[i], res[i].0, res[i].1))
        .collect()
}

/// Aggregate resources.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Resources {
    pub dsp: usize,
    pub bram: usize,
    pub ff: usize,
    pub lut: usize,
}

pub fn total_resources(costs: &[OpCost]) -> Resources {
    costs.iter().fold(Resources::default(), |a, c| Resources {
        dsp: a.dsp + c.dsp,
        bram: a.bram + c.bram,
        ff: a.ff + c.ff,
        lut: a.lut + c.lut,
    })
}

/// Pipeline latency estimate: the bottleneck module's latency (all modules
/// run concurrently — Eqn. 6's `max lat_i`), plus a fill term.
pub fn pipeline_latency(costs: &[OpCost]) -> f64 {
    let bottleneck = costs.iter().map(|c| c.latency).fold(0.0, f64::max);
    let fill: f64 = costs.iter().map(|c| (c.latency * 0.001).min(50.0)).sum();
    bottleneck + fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::Act;

    fn st(tokens: f64, s_k: f64) -> LayerStats {
        LayerStats { s_s: 0.1, s_k, tokens, n: 1 }
    }

    #[test]
    fn matches_paper_dw_formula() {
        // H=W=32, S_s=0.1 → tokens = 102.4 ; k=3, S_k=0.5, C=16, PF=4
        let op = Op::DwConv { k: 3, c: 16, stride: 1, act: Act::Relu6 };
        let c = op_cost(&op, &st(102.4, 0.5), 4, 32, 32);
        let want = 102.4 * (9.0 * 0.5) * (16f64 / 4.0);
        assert!((c.latency - want).abs() < 1e-9);
        assert_eq!(c.dsp, 4);
        // bram: weights 9·16·8 = 1152 bits → ceil(1152/16384/4)·4 = 4, plus SLB.
        assert_eq!(c.bram, 4 + slb_bram(3, 32, 16));
    }

    #[test]
    fn pf_monotonicity() {
        let op = Op::Conv1x1 { cin: 32, cout: 64, act: Act::Relu6 };
        let s = st(500.0, 1.0);
        let mut last = f64::INFINITY;
        for pf in [1, 2, 4, 8, 16, 32] {
            let c = op_cost(&op, &s, pf, 16, 16);
            assert!(c.latency <= last);
            last = c.latency;
            assert_eq!(c.dsp, pf);
        }
    }

    #[test]
    fn bram_partitioning_grows_with_pf() {
        // Large weights: partitioning into PF banks rounds each bank up.
        let n = 3 * 3 * 64 * 64; // 36864 weights → 294912 bits → 18 BRAM
        let b1 = weight_bram(n, 1);
        let b32 = weight_bram(n, 32);
        assert_eq!(b1, 18);
        assert_eq!(b32, 32); // ceil(18/32)·32
        assert!(b32 >= b1);
    }

    #[test]
    fn pipeline_latency_is_bottleneck_dominated() {
        let costs = vec![
            OpCost { latency: 100.0, dsp: 1, bram: 1, ff: 0, lut: 0 },
            OpCost { latency: 5000.0, dsp: 1, bram: 1, ff: 0, lut: 0 },
            OpCost { latency: 200.0, dsp: 1, bram: 1, ff: 0, lut: 0 },
        ];
        let lat = pipeline_latency(&costs);
        assert!(lat >= 5000.0 && lat < 5100.0);
    }
}
