//! The MBConv search space (§3.4.2): number of blocks, per-block stride,
//! and per-layer channel widths, under a parameter budget and a fixed total
//! downsampling ratio.

use crate::model::graph::{Act, Block, NetworkSpec};
use crate::util::Rng;

/// Search-space description.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub w: usize,
    pub h: usize,
    pub n_classes: usize,
    /// Total stride the sampled net must realize (product of block strides
    /// including the stem) — fixed per dataset as in the paper.
    pub total_downsample: usize,
    /// Number of MBConv blocks to sample between.
    pub min_blocks: usize,
    pub max_blocks: usize,
    /// Channel choices.
    pub channels: Vec<usize>,
    /// Expansion choices.
    pub expands: Vec<usize>,
    /// Parameter budget (on-chip weight capacity).
    pub max_params: usize,
}

impl SearchSpace {
    /// Default space for a dataset resolution (mirrors the paper's setup:
    /// MBConv models sized for the ZCU102 on-chip buffer).
    pub fn for_dataset(w: usize, h: usize, n_classes: usize) -> SearchSpace {
        let total_downsample = if w.min(h) >= 128 {
            32
        } else if w.min(h) >= 64 {
            16
        } else {
            8
        };
        SearchSpace {
            w,
            h,
            n_classes,
            total_downsample,
            min_blocks: 3,
            max_blocks: 8,
            channels: vec![8, 12, 16, 24, 32, 48, 64, 96],
            expands: vec![1, 2, 4, 6],
            max_params: 400_000,
        }
    }
}

/// Sample one architecture. Strides: the stem always takes one 2× step;
/// the remaining log2(total/2) 2× steps are placed at random block
/// positions (monotone resolution schedule). Channels are sampled
/// non-decreasing, as mobile nets do.
pub fn sample_network(space: &SearchSpace, rng: &mut Rng, name: &str) -> NetworkSpec {
    loop {
        let n_blocks = space.min_blocks + rng.index(space.max_blocks - space.min_blocks + 1);
        let n_down_left = (space.total_downsample as f64).log2() as usize - 1;
        // Choose which blocks downsample.
        let mut strides = vec![1usize; n_blocks];
        let idx = rng.sample_indices(n_blocks, n_down_left.min(n_blocks));
        for i in idx {
            strides[i] = 2;
        }
        // Non-decreasing channel ladder.
        let mut ch_idx = rng.index(3); // start small
        let stem_c = space.channels[rng.index(2)];
        let mut blocks = vec![Block::Stem { k: 3, cout: stem_c, stride: 2 }];
        for &s in &strides {
            if rng.chance(0.5) && ch_idx + 1 < space.channels.len() {
                ch_idx += 1;
            }
            blocks.push(Block::MBConv {
                cout: space.channels[ch_idx],
                expand: *rng.choose(&space.expands),
                k: 3,
                stride: s,
            });
        }
        let head = space.channels[(ch_idx + 2).min(space.channels.len() - 1)] * 2;
        blocks.push(Block::Conv1x1 { cout: head, act: Act::Relu6 });
        blocks.push(Block::PoolFc);
        let spec = NetworkSpec {
            name: name.to_string(),
            w: space.w,
            h: space.h,
            cin: 2,
            n_classes: space.n_classes,
            blocks,
        };
        if spec.param_count() <= space.max_params
            && spec.total_downsample() == space.total_downsample
        {
            return spec;
        }
        // Resample on budget/stride miss (bounded by construction: strides
        // always multiply to the target; only the budget can reject).
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_constraints() {
        let space = SearchSpace::for_dataset(128, 128, 10);
        let mut rng = Rng::new(1);
        for i in 0..20 {
            let net = sample_network(&space, &mut rng, &format!("s{i}"));
            assert_eq!(net.total_downsample(), space.total_downsample, "sample {i}");
            assert!(net.param_count() <= space.max_params, "sample {i}");
            assert!(net.blocks.len() >= space.min_blocks + 2);
            // Must end with PoolFc.
            assert!(matches!(net.blocks.last(), Some(Block::PoolFc)));
        }
    }

    #[test]
    fn samples_are_diverse() {
        let space = SearchSpace::for_dataset(64, 64, 3);
        let mut rng = Rng::new(2);
        let nets: Vec<NetworkSpec> = (0..10)
            .map(|i| sample_network(&space, &mut rng, &format!("s{i}")))
            .collect();
        let distinct: std::collections::BTreeSet<String> =
            nets.iter().map(|n| format!("{:?}", n.blocks)).collect();
        assert!(distinct.len() >= 5, "only {} distinct architectures", distinct.len());
    }

    #[test]
    fn small_resolution_uses_smaller_downsample() {
        let s34 = SearchSpace::for_dataset(34, 34, 10);
        assert_eq!(s34.total_downsample, 8);
        let s240 = SearchSpace::for_dataset(240, 180, 24);
        assert_eq!(s240.total_downsample, 32);
    }
}
