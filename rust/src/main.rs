//! `esda` — leader binary for the ESDA reproduction.
//!
//! Subcommands:
//! - `gen-data   [--out artifacts/data] [--train N] [--test N] [--seed S]`
//!   generate the synthetic event datasets consumed by the python training
//!   path and the benches.
//! - `optimize   --dataset <name> [--model mbv2|compact|tiny]`
//!   run the Eqn. 6 sparsity-aware allocator and print the configuration.
//! - `simulate   --dataset <name> [--model ...] [--samples N]`
//!   cycle-simulate inferences and print latency/bottleneck reports.
//! - `search     --dataset <name> [--samples N] [--top-k K]`
//!   run the two-step NAS and print the candidate table.
//! - `serve      --dataset <name> [--requests N] [--backend sim|func|dense]
//!               [--workers N] [--queue D] [--drop-policy block|drop-oldest]
//!               [--batch B] [--pool class=count[@batch],...]
//!               [--source synth|replay:path[@speed]|tail:path|udp:port|tcp:port]
//!               [--slo-ms N] [--tenant name=weight[,slo_ms],...]
//!               [--cost-profile path] [--scale-interval-ms N] [--scale-window-ms N]`
//!   run the sharded serving runtime (accelerator worker replicas behind
//!   an admission-controlled ingress queue; each worker drains up to B
//!   already-queued requests per backend visit) and print per-worker
//!   metrics including the realized batch-size distribution. With
//!   `--pool` (e.g. `--pool func=4,sim=1,dense=1`) the runtime becomes a
//!   heterogeneous pool: per-replica backend instances grouped into
//!   classes, each with its own batch affinity, and a cost-aware router
//!   sending each request to the class minimizing predicted completion
//!   time; the report adds a per-class breakdown. `--source` feeds the
//!   runtime from a recorded `.esda` dataset replayed at wall-clock rate
//!   × speed (streamed sample-at-a-time — long captures never
//!   materialize), or by tailing a growing capture file; `--slo-ms N`
//!   gives every request the deadline `arrival + N ms` — expired requests
//!   are dropped at the ingress, predicted-infeasible ones are shed at
//!   the router, and the report adds SLO attainment with the
//!   deadline-drop breakdown. A pool class spelled as a range
//!   (`--pool func=1..4`) is autoscaled: a controller samples its
//!   backlog, windowed utilization, and deadline-drop rate, growing and
//!   shrinking the replica count inside the band (tick/window tunable
//!   via `--scale-interval-ms`/`--scale-window-ms`); the report gains
//!   the scaling log and a replica-band column. `--cost-profile path`
//!   seeds every class's routing cost model from a previous run's
//!   profile (no cold-start probes) and rewrites the file with the
//!   updated models at shutdown. `--source udp:port` / `tcp:port` binds
//!   a socket front door speaking the compact event-packet format (see
//!   `coordinator::net`): UDP takes one packet per datagram, TCP takes
//!   length-prefixed packet streams per connection, and both land
//!   packets in DMA-style buffers flushed on size or timeout. `--tenant`
//!   (e.g. `--tenant cam0=3,5.0,cam1=1`) declares the tenant table:
//!   each tenant's ingress quota is its weighted fair share of the
//!   queue depth, an optional per-tenant SLO (ms) overrides the global
//!   `--slo-ms`, and the report adds a per-tenant breakdown including
//!   recoverable ingest rejects. `--delta` (or `--delta-max-frac F`,
//!   default 0.35, which implies it) switches `func` replicas to
//!   incremental (delta) inference: each stream's previous window is
//!   cached and only changed sites re-execute, falling back to a full
//!   recompute above the dirty-fraction threshold; under a router,
//!   streams are sticky-routed back to the worker holding their cache.
//!   `--overlap F` (with `--streams N`) makes the synthetic source emit
//!   N interleaved sliding-window streams whose consecutive windows
//!   share fraction F of their events — the workload delta inference is
//!   for. The report adds the delta hit/fallback/sticky line.
//!   `--model name=arch` (repeatable; arch ∈ mbv2|compact|tiny) turns
//!   the pool into a multi-model **fleet**: one compiled network per
//!   model, one replica class per model (`--workers` replicas each),
//!   requests routed only to their model's classes, and a per-model
//!   report table with its own conservation identity. `--model-mix
//!   name=w,...` weights the synthetic/replay traffic across the fleet
//!   (uniform without it); `--swap name=arch@secs` hot-swaps the named
//!   model to a freshly built arch after `secs` seconds (atomic flip —
//!   no request lost or torn); `--shadow name=arch@frac` mirrors
//!   fraction `frac` of the named model's served traffic to a candidate
//!   backend and bit-exactly compares predictions, reporting
//!   disagreement counts; `--shadow-capture path` appends every
//!   disagreeing sample to a replayable `.esda` capture. `--labels
//!   path` pairs a `--source replay:` capture with a sidecar of one
//!   `u32` label per sample so replayed real captures contribute to
//!   accuracy scoring.
//! - `infer      --hlo artifacts/<stem>.hlo.txt`
//!   load an AOT artifact and run a smoke inference via PJRT (needs the
//!   `pjrt` feature).
//! - `lint       [--fix-plan] [--json] [paths…]`
//!   run the in-tree static-analysis pass (see `lint`) over `rust/src`
//!   plus `examples/` and `rust/benches/` (or the given
//!   files/directories): panic-freedom on the serving path, zero-alloc
//!   hot-path regions, checked wire casts, metrics/report/CLI drift,
//!   and the concurrency-discipline rules (lock ranks, guard spans,
//!   atomic contracts). Findings print as `file:line: rule: message`
//!   and the exit code is non-zero when any exist; `--fix-plan` adds a
//!   suggested remediation per finding; `--json` emits one
//!   machine-readable document on stdout instead (for CI artifacts).

use esda::coordinator::{
    run_pool, run_pool_source, run_server, run_server_source, synthetic_source, Backend, Dense,
    DropPolicy, EventSource, Functional, MixSource, NetConfig, NetSource, ReplicaPool,
    ReplicaSpec, ReplaySource, ServerConfig, Shared, ShadowCaptureConfig, ShadowConfig,
    Simulator, Swappable, TailSource, TenantConfig,
};
use esda::events::{io::generate_dataset_files, repr::histogram2_norm, DatasetProfile};
use esda::hwopt::{
    allocate, power::PowerModel, power::CLOCK_HZ, stats::collect_stats_for_profile, Budget,
};
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::nas::{search, SearchConfig, SearchSpace};
use esda::report::Table;
use esda::util::cli::Args;
use esda::util::Rng;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(raw, &["verbose", "delta", "fix-plan", "json"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "gen-data" => cmd_gen_data(&args),
        "optimize" => cmd_optimize(&args),
        "simulate" => cmd_simulate(&args),
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "infer" => cmd_infer(&args),
        "lint" => cmd_lint(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "esda — composable dynamic sparse dataflow architecture (FPGA'24 reproduction)\n\
         usage: esda <gen-data|optimize|simulate|search|serve|infer|lint> [flags]\n\
         see `rust/src/main.rs` docs for per-command flags"
    );
}

fn profile_from(args: &Args) -> Result<DatasetProfile, String> {
    let name = args.get_or("dataset", "n_mnist");
    DatasetProfile::by_name(name).ok_or_else(|| {
        format!(
            "unknown dataset '{name}' (choose from: {})",
            DatasetProfile::all().iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
        )
    })
}

fn arch_spec(arch: &str, p: &DatasetProfile) -> NetworkSpec {
    match arch {
        "mbv2" => NetworkSpec::mobilenet_v2_05("mbv2", p.w, p.h, p.n_classes),
        "tiny" => NetworkSpec::tiny(p.w, p.h, p.n_classes),
        _ => NetworkSpec::compact("compact", p.w, p.h, p.n_classes),
    }
}

fn model_from(args: &Args, p: &DatasetProfile) -> NetworkSpec {
    arch_spec(args.get_or("model", "compact"), p)
}

/// Quantize one architecture for `p` (fleet serving compiles one of
/// these per `--model name=arch` entry; all share the dataset's
/// deterministic calibration stream).
fn qnet_for_arch(arch: &str, p: &DatasetProfile, seed: u64) -> esda::model::quant::QuantizedNet {
    let spec = arch_spec(arch, p);
    let mut rng = Rng::new(seed);
    let w = FloatWeights::random(&spec, seed);
    let calib: Vec<_> = (0..3)
        .map(|i| {
            let es = p.sample(i % p.n_classes, &mut rng);
            histogram2_norm(&es, p.w, p.h, 8.0)
        })
        .collect();
    quantize_network(&spec, &w, &calib)
}

fn cmd_gen_data(args: &Args) -> Result<(), String> {
    let out = std::path::PathBuf::from(args.get_or("out", "artifacts/data"));
    let n_train = args.get_usize("train", 24)?;
    let n_test = args.get_usize("test", 8)?;
    let seed = args.get_u64("seed", 0xE5DA)?;
    for p in DatasetProfile::all() {
        let (tr, te) = generate_dataset_files(&p, &out, n_train, n_test, seed)
            .map_err(|e| format!("{}: {e}", p.name))?;
        println!("{}: wrote {} and {}", p.name, tr.display(), te.display());
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<(), String> {
    let p = profile_from(args)?;
    let spec = model_from(args, &p);
    let n_stat = args.get_usize("stat-samples", 8)?;
    let stats = collect_stats_for_profile(&spec, &p, n_stat, 1);
    let alloc = allocate(&spec, &stats, &Budget::zcu102())
        .ok_or("model does not fit the ZCU102 budget")?;
    let pm = PowerModel::calibrated();
    let mut t = Table::new(
        &format!("Eqn.6 allocation — {} on {}", spec.name, p.name),
        &["op", "S_s", "S_k", "PF", "lat(cyc)", "DSP", "BRAM"],
    );
    for (i, op) in spec.ops().iter().enumerate() {
        t.row(vec![
            format!("{op:?}"),
            format!("{:.3}", stats[i].s_s),
            format!("{:.3}", stats[i].s_k),
            alloc.pf[i].to_string(),
            format!("{:.0}", alloc.costs[i].latency),
            alloc.costs[i].dsp.to_string(),
            alloc.costs[i].bram.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "bottleneck {:.0} cycles = {:.3} ms @187MHz | total DSP {} BRAM {} | est. power {:.2} W | energy {:.2} mJ/inf",
        alloc.latency,
        alloc.latency / CLOCK_HZ * 1e3,
        alloc.resources.dsp,
        alloc.resources.bram,
        pm.watts(&alloc.resources),
        pm.energy_mj(&alloc.resources, alloc.latency, CLOCK_HZ),
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let p = profile_from(args)?;
    let spec = model_from(args, &p);
    let n_samples = args.get_usize("samples", 3)?;
    let seed = args.get_u64("seed", 7)?;
    let mut rng = Rng::new(seed);
    let w = FloatWeights::random(&spec, seed);
    let calib: Vec<_> = (0..3)
        .map(|i| {
            let es = p.sample(i % p.n_classes, &mut rng);
            histogram2_norm(&es, p.w, p.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &w, &calib);
    let stats = collect_stats_for_profile(&spec, &p, 4, seed);
    let alloc = allocate(&spec, &stats, &Budget::zcu102()).ok_or("does not fit")?;
    let cfg = esda::arch::HwConfig { pf: alloc.pf.clone(), fifo_depth: 8 };
    for s in 0..n_samples {
        let es = p.sample(s % p.n_classes, &mut rng);
        let input = histogram2_norm(&es, p.w, p.h, 8.0);
        let (logits, report) = esda::arch::simulate_inference(&qnet, &cfg, &input, 20_000_000_000)
            .map_err(|e| e.to_string())?;
        println!(
            "sample {s}: nnz {} ({:.1}%), {} cycles = {:.3} ms @187MHz, argmax {}",
            input.nnz(),
            input.nz_ratio() * 100.0,
            report.cycles,
            report.cycles as f64 / CLOCK_HZ * 1e3,
            esda::model::exec::argmax(&logits),
        );
        if args.has("verbose") {
            println!("{report}");
        }
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<(), String> {
    let p = profile_from(args)?;
    let space = SearchSpace::for_dataset(p.w, p.h, p.n_classes);
    let cfg = SearchConfig {
        n_samples: args.get_usize("samples", 24)?,
        top_k: args.get_usize("top-k", 4)?,
        ..Default::default()
    };
    let out = search(&p, &space, &cfg);
    let mut t = Table::new(
        &format!("NAS candidates — {}", p.name),
        &["name", "params", "blocks", "thr (inf/s)", "lat (ms)", "DSP", "BRAM", "probe acc"],
    );
    for c in &out {
        t.row(vec![
            c.spec.name.clone(),
            c.spec.param_count().to_string(),
            c.spec.blocks.len().to_string(),
            format!("{:.0}", c.throughput),
            format!("{:.3}", c.alloc.latency / CLOCK_HZ * 1e3),
            c.alloc.resources.dsp.to_string(),
            c.alloc.resources.bram.to_string(),
            format!("{:.2}", c.accuracy.unwrap_or(f64::NAN)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

const FLEET_ARCHS: [&str; 3] = ["mbv2", "compact", "tiny"];

fn cmd_serve(args: &Args) -> Result<(), String> {
    let p = profile_from(args)?;
    // `--model name=arch` entries (any value containing '=') switch the
    // run into fleet mode; a bare `--model arch` keeps its original
    // meaning as the single-model architecture selector.
    let fleet: Vec<(String, String)> = {
        let vals = args.get_all("model");
        let entries: Vec<(String, String)> = vals
            .iter()
            .filter_map(|v| v.split_once('='))
            .map(|(n, a)| (n.to_string(), a.to_string()))
            .collect();
        if !entries.is_empty() && entries.len() != vals.len() {
            return Err(
                "--model: cannot mix `name=arch` fleet entries with a bare architecture \
                 selector"
                    .into(),
            );
        }
        for (name, arch) in &entries {
            if name.is_empty() {
                return Err("--model: fleet entries need a non-empty name".into());
            }
            if !FLEET_ARCHS.contains(&arch.as_str()) {
                return Err(format!(
                    "--model {name}={arch}: unknown arch '{arch}' (choose from: {})",
                    FLEET_ARCHS.join(", ")
                ));
            }
        }
        entries
    };
    let fleet_mode = !fleet.is_empty();
    let spec = model_from(args, &p);
    let seed = args.get_u64("seed", 3)?;
    let mut rng = Rng::new(seed);
    let w = FloatWeights::random(&spec, seed);
    let calib: Vec<_> = (0..3)
        .map(|i| {
            let es = p.sample(i % p.n_classes, &mut rng);
            histogram2_norm(&es, p.w, p.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &w, &calib);
    let n_ops = spec.ops().len();
    let policy_raw = args.get_or("drop-policy", "block");
    let workers = args.get_usize("workers", 1)?;
    if workers == 0 {
        return Err("--workers must be >= 1".into());
    }
    let queue_depth = args.get_usize("queue", 4)?;
    if queue_depth == 0 {
        return Err("--queue must be >= 1".into());
    }
    let batch = args.get_usize("batch", 1)?;
    if batch == 0 {
        return Err("--batch must be >= 1".into());
    }
    // Incremental (delta) inference: --delta-max-frac implies --delta so
    // tuning the threshold doesn't also require the switch.
    let delta_max_frac = args.get_f64("delta-max-frac", 0.35)?;
    let delta = args.has("delta") || args.get("delta-max-frac").is_some();
    if delta && !(delta_max_frac > 0.0 && delta_max_frac <= 1.0) {
        return Err(format!("--delta-max-frac must be in (0, 1], got {delta_max_frac}"));
    }
    let overlap = args.get_f64("overlap", 0.0)?;
    if !(0.0..=1.0).contains(&overlap) {
        return Err(format!("--overlap must be in [0, 1], got {overlap}"));
    }
    let streams = args.get_usize("streams", 4)?;
    if streams == 0 {
        return Err("--streams must be >= 1".into());
    }
    let slo = match args.get("slo-ms") {
        None => None,
        Some(v) => {
            let ms: f64 =
                v.parse().map_err(|_| format!("--slo-ms: expected number, got '{v}'"))?;
            // Upper bound keeps Duration::from_secs_f64 from panicking on
            // absurd values; 1e9 ms ≈ 11.6 days is already no SLO at all.
            if !(ms.is_finite() && ms > 0.0 && ms <= 1e9) {
                return Err(format!("--slo-ms must be in (0, 1e9], got {ms}"));
            }
            Some(std::time::Duration::from_secs_f64(ms / 1e3))
        }
    };
    // Tenant table: weighted fair shares of the ingress queue depth,
    // each with an optional SLO overriding the global --slo-ms. Absent,
    // the server runs its implicit single tenant (front door inert).
    let tenants: Vec<TenantConfig> = match args.get("tenant") {
        None => Vec::new(),
        Some(raw) => {
            let specs =
                esda::util::cli::parse_tenant_spec(raw).map_err(|e| format!("--tenant: {e}"))?;
            let mut out = Vec::with_capacity(specs.len());
            for t in specs {
                let tc = TenantConfig::new(t.name.as_str(), t.weight);
                out.push(match t.slo_ms {
                    None => tc,
                    Some(ms) if ms <= 1e9 => {
                        tc.with_slo(std::time::Duration::from_secs_f64(ms / 1e3))
                    }
                    Some(ms) => {
                        return Err(format!(
                            "--tenant {}: slo must be <= 1e9 ms, got {ms}",
                            t.name
                        ))
                    }
                });
            }
            out
        }
    };
    // Cost-model persistence: seed from the profile when it exists (a
    // missing file just means a cold first run — the same flag rewrites
    // it at shutdown); a *corrupt* profile is an error, not a cold start.
    let cost_profile_path = args.get("cost-profile").map(std::path::PathBuf::from);
    let cost_profile = match &cost_profile_path {
        Some(p) if p.exists() => {
            // Version-mismatched profiles load leniently as empty (cold
            // start) with a warning — only garbage is an error.
            let (profile, warning) = esda::coordinator::CostProfile::load(p)?;
            if let Some(w) = warning {
                eprintln!("warning: {w}");
            }
            Some(profile)
        }
        _ => None,
    };
    let scale_interval_ms = args.get_f64("scale-interval-ms", 20.0)?;
    let scale_window_ms = args.get_f64("scale-window-ms", 200.0)?;
    if !(scale_interval_ms > 0.0 && scale_interval_ms <= 1e6)
        || !(scale_window_ms >= scale_interval_ms && scale_window_ms <= 1e7)
    {
        return Err(format!(
            "--scale-interval-ms must be in (0, 1e6] and --scale-window-ms in \
             [interval, 1e7], got {scale_interval_ms} / {scale_window_ms}"
        ));
    }
    // Shadow deployments: mirror a fraction of a fleet model's served
    // traffic to a candidate build and compare predictions bit-exactly.
    let mut shadows = Vec::new();
    for raw in args.get_all("shadow") {
        let s = esda::util::cli::parse_shadow_spec(raw).map_err(|e| format!("--shadow: {e}"))?;
        if !fleet.iter().any(|(n, _)| *n == s.model) {
            return Err(format!(
                "--shadow: unknown model '{}' (declare the fleet via --model name=arch)",
                s.model
            ));
        }
        if !FLEET_ARCHS.contains(&s.arch.as_str()) {
            return Err(format!("--shadow: unknown arch '{}'", s.arch));
        }
        // A distinct seed gives the candidate its own weights, so
        // same-arch shadows still exercise the comparison honestly.
        let candidate: std::sync::Arc<dyn Backend> =
            std::sync::Arc::new(Functional::new(qnet_for_arch(&s.arch, &p, seed + 17)));
        shadows.push(ShadowConfig { model: s.model, candidate, fraction: s.fraction });
    }
    let shadow_capture = match args.get("shadow-capture") {
        None => None,
        Some(path) if shadows.is_empty() => {
            return Err(format!("--shadow-capture {path}: needs at least one --shadow"))
        }
        Some(path) => Some(ShadowCaptureConfig {
            path: std::path::PathBuf::from(path),
            ..ShadowCaptureConfig::default()
        }),
    };
    // Hot swap: after `secs` seconds flip the named model's backend to a
    // freshly built arch — every Shared replica handle sees the new build
    // on its next classify call, with no request lost or torn.
    let swap_spec = match args.get("swap") {
        None => None,
        Some(raw) => {
            let s = esda::util::cli::parse_swap_spec(raw).map_err(|e| format!("--swap: {e}"))?;
            if !fleet.iter().any(|(n, _)| *n == s.model) {
                return Err(format!(
                    "--swap: unknown model '{}' (declare the fleet via --model name=arch)",
                    s.model
                ));
            }
            if !FLEET_ARCHS.contains(&s.arch.as_str()) {
                return Err(format!("--swap: unknown arch '{}'", s.arch));
            }
            Some(s)
        }
    };
    // Traffic mix across the fleet: weights aligned to --model order;
    // models absent from the spec get weight zero. Uniform without it.
    let mix: Vec<usize> = match args.get("model-mix") {
        None => vec![1; fleet.len().max(1)],
        Some(_) if !fleet_mode => {
            return Err("--model-mix: needs a fleet (declare it via --model name=arch)".into())
        }
        Some(raw) => {
            let entries =
                esda::util::cli::parse_mix_spec(raw).map_err(|e| format!("--model-mix: {e}"))?;
            let mut weights = vec![0usize; fleet.len()];
            for (name, w) in &entries {
                match fleet.iter().position(|(n, _)| n == name) {
                    Some(i) => weights[i] = *w,
                    None => return Err(format!("--model-mix: unknown model '{name}'")),
                }
            }
            if weights.iter().all(|w| *w == 0) {
                return Err("--model-mix: all weights are zero".into());
            }
            weights
        }
    };
    let cfg = ServerConfig {
        n_requests: args.get_usize("requests", 32)?,
        seed,
        clip: 8.0,
        workers,
        queue_depth,
        drop_policy: DropPolicy::parse(policy_raw)
            .ok_or_else(|| format!("--drop-policy: expected block|drop-oldest, got '{policy_raw}'"))?,
        batch,
        slo,
        autoscale: Some(esda::coordinator::AutoscaleConfig {
            interval: std::time::Duration::from_secs_f64(scale_interval_ms / 1e3),
            window: std::time::Duration::from_secs_f64(scale_window_ms / 1e3),
            ..Default::default()
        }),
        cost_profile,
        tenants,
        overlap,
        streams,
        shadows,
        shadow_capture,
    };
    let source_spec = esda::util::cli::parse_source_spec(args.get_or("source", "synth"))?;
    if args.get("labels").is_some()
        && !matches!(source_spec, esda::util::cli::SourceSpec::Replay { .. })
    {
        return Err("--labels pairs with --source replay:path only".into());
    }
    // A non-synthetic source replaces the generated stream: build it now
    // and check its geometry against the dataset profile the network was
    // quantized for (a mismatched replay would build maps of the wrong
    // shape). `--requests` caps a replay only when explicitly given; a
    // tail follows the file until its producer goes quiet.
    let source: Option<Box<dyn EventSource>> = match &source_spec {
        esda::util::cli::SourceSpec::Synth => None,
        esda::util::cli::SourceSpec::Replay { path, speed } => {
            let mut src = ReplaySource::open(std::path::Path::new(path), *speed)
                .map_err(|e| e.to_string())?;
            if let Some(lp) = args.get("labels") {
                // One u32 ground-truth label per sample; a count mismatch
                // against the capture header is fatal at build time.
                src = src.with_labels(std::path::Path::new(lp)).map_err(|e| e.to_string())?;
            }
            if args.get("requests").is_some() {
                src = src.with_limit(cfg.n_requests);
            }
            Some(Box::new(src))
        }
        esda::util::cli::SourceSpec::Tail { path } => {
            let mut src = TailSource::open(std::path::Path::new(path))
                .map_err(|e| e.to_string())?;
            if args.get("requests").is_some() {
                src = src.with_limit(cfg.n_requests);
            }
            Some(Box::new(src))
        }
        esda::util::cli::SourceSpec::Udp { port } | esda::util::cli::SourceSpec::Tcp { port } => {
            // Socket front door: geometry comes from the dataset profile
            // (packets are validated against it at the boundary) and the
            // boundary's tenant table matches the server's.
            let ncfg = NetConfig {
                tenants: cfg.tenants.len().max(1),
                models: fleet.len().max(1),
                ..NetConfig::default()
            };
            let src = match &source_spec {
                esda::util::cli::SourceSpec::Udp { .. } => NetSource::udp(*port, p.w, p.h, ncfg),
                _ => NetSource::tcp(*port, p.w, p.h, ncfg),
            };
            let mut src = src.map_err(|e| e.to_string())?;
            if args.get("requests").is_some() {
                src = src.with_limit(cfg.n_requests);
            }
            Some(Box::new(src))
        }
    };
    if let Some(src) = &source {
        if src.geometry() != (p.w, p.h) {
            let (sw, sh) = src.geometry();
            return Err(format!(
                "{}: geometry {sw}x{sh} does not match dataset '{}' ({}x{}) — pass the \
                 matching --dataset",
                src.name(),
                p.name,
                p.w,
                p.h
            ));
        }
    }
    if fleet_mode {
        for spelled in ["pool", "backend"] {
            if args.get(spelled).is_some() {
                return Err(format!(
                    "--{spelled} and --model name=arch fleets are mutually exclusive: the \
                     fleet builds one functional class per model"
                ));
            }
        }
        if delta {
            return Err("--delta is not yet supported for --model fleets".into());
        }
    }
    let pooled = args.get("pool").is_some();
    if pooled && args.get("backend").is_some() {
        return Err(
            "--backend and --pool are mutually exclusive: name the backend as a pool \
             class instead (e.g. --pool dense=2,func=1)"
                .into(),
        );
    }
    if pooled && args.get("workers").is_some() {
        return Err(
            "--workers and --pool are mutually exclusive: the pool spec carries each \
             class's replica count (e.g. --pool func=4)"
                .into(),
        );
    }
    if pooled && args.get("batch").is_some() {
        return Err(
            "--batch and --pool are mutually exclusive: set a class's batch affinity in \
             the pool spec (e.g. --pool func=4@8)"
                .into(),
        );
    }
    let r = if fleet_mode {
        // Multi-model fleet: one compiled network per --model entry, one
        // functional replica class per model (tagged so the router only
        // offers a request to its own model's class), every replica of a
        // model sharing that model's swappable backend handle.
        use std::sync::Arc;
        let mut specs = Vec::new();
        let mut handles: Vec<Arc<Swappable>> = Vec::new();
        for (name, arch) in &fleet {
            let qnet = qnet_for_arch(arch, &p, seed);
            let handle =
                Arc::new(Swappable::new(name.clone(), Arc::new(Functional::new(qnet))));
            let shared = Arc::clone(&handle);
            specs.push(
                ReplicaSpec::new(name.clone(), workers, batch, move |_| {
                    Ok(Box::new(Shared(Arc::clone(&shared) as Arc<dyn Backend>)))
                })
                .for_model(name.clone()),
            );
            handles.push(handle);
        }
        let pool = ReplicaPool::build(specs).map_err(|e| e.to_string())?;
        if let Some(s) = &swap_spec {
            let idx = fleet.iter().position(|(n, _)| *n == s.model).unwrap_or(0);
            let target = Arc::clone(&handles[idx]);
            // Built eagerly so the mid-run flip costs one Arc exchange,
            // not a network compile.
            let next: Arc<dyn Backend> =
                Arc::new(Functional::new(qnet_for_arch(&s.arch, &p, seed + 1)));
            let at = std::time::Duration::from_secs_f64(s.at_secs);
            // Detached: the flip is atomic and idempotent, so a swap
            // scheduled past the run's end is harmless.
            std::thread::spawn(move || {
                std::thread::sleep(at);
                target.swap(next);
            });
        }
        let base: Box<dyn EventSource> = match source {
            Some(src) => src,
            None => Box::new(synthetic_source(&p, &cfg)),
        };
        let src = Box::new(MixSource::new(base, &mix));
        run_pool_source(src, &pool, &cfg).map_err(|e| e.to_string())?
    } else if let Some(pool_raw) = args.get("pool") {
        // Heterogeneous pool: per-replica backend instances grouped into
        // classes, cost-aware routing between them. The pool spec defines
        // the worker count and per-class batch affinity (explicit
        // `--workers`/`--batch`/`--backend` were rejected above).
        let items =
            esda::util::cli::parse_pool_spec(pool_raw).map_err(|e| format!("--pool: {e}"))?;
        let mut specs = Vec::new();
        for it in &items {
            let s = match it.class.as_str() {
                // With --delta every func replica of the class shares one
                // delta store, so sticky-routing misses and replica churn
                // lose no cached windows.
                "func" if delta => {
                    ReplicaSpec::functional_delta(it.count, qnet.clone(), delta_max_frac)
                }
                "func" => ReplicaSpec::functional(it.count, qnet.clone()),
                "sim" => ReplicaSpec::simulator(
                    it.count,
                    qnet.clone(),
                    esda::arch::HwConfig::uniform(n_ops, 16),
                ),
                "dense" => {
                    let stem = args.get_or("hlo", "artifacts/compact_n_mnist.hlo.txt");
                    ReplicaSpec::dense(it.count, std::path::PathBuf::from(stem))
                }
                other => {
                    return Err(format!(
                        "--pool: unknown replica class '{other}' (choose from: func, sim, dense)"
                    ))
                }
            };
            let s = match it.batch {
                Some(b) => s.with_batch(b),
                None => s,
            };
            // `class=min..max` hands the class to the autoscaler.
            specs.push(match it.max {
                Some(m) => s.with_max_replicas(m),
                None => s,
            });
        }
        let pool = ReplicaPool::build(specs).map_err(|e| e.to_string())?;
        match source {
            Some(src) => run_pool_source(src, &pool, &cfg).map_err(|e| e.to_string())?,
            None => run_pool(&p, &pool, &cfg).map_err(|e| e.to_string())?,
        }
    } else {
        let backend_name = args.get_or("backend", "func").to_string();
        if delta && backend_name != "func" {
            return Err(format!(
                "--delta requires the functional backend, got --backend {backend_name}"
            ));
        }
        let backend: Box<dyn Backend> = match backend_name.as_str() {
            "sim" => Box::new(Simulator::new(qnet, esda::arch::HwConfig::uniform(n_ops, 16))),
            "dense" => {
                let stem = args.get_or("hlo", "artifacts/compact_n_mnist.hlo.txt").to_string();
                let engine = esda::runtime::Engine::load(std::path::Path::new(&stem))
                    .map_err(|e| e.to_string())?;
                Box::new(Dense::new(engine))
            }
            _ if delta => Box::new(Functional::new(qnet).with_delta(delta_max_frac)),
            _ => Box::new(Functional::new(qnet)),
        };
        if workers > 1 && backend_name == "dense" {
            eprintln!(
                "note: a shared dense backend serializes inferences behind a mutex — \
                 --workers {workers} adds no accelerator parallelism (use \
                 `--pool dense={workers}` for one engine per replica)"
            );
        }
        match source {
            Some(src) => {
                run_server_source(src, backend.as_ref(), &cfg).map_err(|e| e.to_string())?
            }
            None => run_server(&p, backend.as_ref(), &cfg).map_err(|e| e.to_string())?,
        }
    };
    let m = &r.metrics;
    println!("{}", esda::report::summary_line(m));
    if m.ingest_rejects > 0 {
        println!(
            "ingest: {} recoverable reject(s) skipped at the source boundary",
            m.ingest_rejects
        );
    }
    if let Some(line) = esda::report::slo_line(m) {
        println!("{line}");
    }
    if let Some(line) = esda::report::delta_line(m) {
        println!("{line}");
    }
    for line in esda::report::scaling_log(m) {
        println!("autoscale {line}");
    }
    if m.mean_batch() > 1.0 {
        let bp = m.batch_percentiles();
        println!(
            "micro-batching: mean {:.2} req/visit | p50 {:.0} p99 {:.0} max {:.0} | {} visit(s)",
            m.mean_batch(),
            bp.p50,
            bp.p99,
            bp.max,
            m.batch_sizes.len(),
        );
    }
    if m.per_tenant.len() > 1 {
        println!("{}", esda::report::tenant_table(m).render());
    }
    if pooled || fleet_mode {
        println!("{}", esda::report::pool_table(m).render());
    }
    if m.per_model.len() > 1 || m.per_model.iter().any(|ms| ms.shadow_mirrored > 0) {
        println!("{}", esda::report::model_table(m).render());
    }
    if let Some(line) = esda::report::shadow_line(m) {
        println!("{line}");
    }
    if m.per_worker.len() > 1 || args.has("verbose") {
        println!("{}", esda::report::serving_table(m).render());
    }
    if let Some(ms) = m.mean_sim_latency_ms(CLOCK_HZ) {
        println!("simulated hardware latency: {ms:.3} ms/inference @187MHz");
    }
    // Rewrite the cost profile with everything this run learned, so the
    // next `serve --cost-profile` starts with seeded routers.
    if let Some(p) = &cost_profile_path {
        if m.cost_profile.is_empty() {
            println!(
                "cost profile: nothing observed (single-class run learns no routing \
                 costs) — {} left unchanged",
                p.display()
            );
        } else {
            m.cost_profile.save(p)?;
            println!("cost profile rewritten -> {}", p.display());
        }
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<(), String> {
    let hlo = args.get("hlo").ok_or("--hlo <path> required")?;
    let engine = esda::runtime::Engine::load(std::path::Path::new(hlo)).map_err(|e| e.to_string())?;
    println!(
        "loaded {} ({}x{}x{} -> {} classes) on {} device(s)",
        hlo, engine.h, engine.w, engine.c, engine.n_classes, engine.device_count()
    );
    let dense = vec![0.5f32; engine.h * engine.w * engine.c];
    let logits = engine.infer_dense(&dense).map_err(|e| e.to_string())?;
    println!("logits: {logits:?}");
    Ok(())
}

/// `esda lint [--fix-plan] [--json] [paths…]` — run the in-tree
/// static-analysis pass (panic-freedom, hot-path allocations, wire
/// casts, drift, concurrency discipline; see the `lint` module docs)
/// and exit non-zero on any finding.
fn cmd_lint(args: &Args) -> Result<(), String> {
    use std::path::PathBuf;
    let mut roots: Vec<PathBuf> = args.positional()[1..].iter().map(PathBuf::from).collect();
    if roots.is_empty() {
        let root = ["rust/src", "src"].iter().map(PathBuf::from).find(|p| p.is_dir());
        roots.push(root.ok_or("no rust/src (or src) here — pass explicit paths to lint")?);
        // The binaries ride along by default: panic/print/cast apply to
        // them too (each root is taken only where it exists, so the
        // walk works from the repo root and from `rust/`).
        for extra in ["examples", "rust/benches", "benches"] {
            let p = PathBuf::from(extra);
            if p.is_dir() {
                roots.push(p);
            }
        }
    }
    let readme =
        ["README.md", "../README.md"].iter().find_map(|p| std::fs::read_to_string(p).ok());
    let files = esda::lint::collect_files(&roots)?;
    let findings = esda::lint::lint_sources(&files, readme.as_deref());
    if args.has("json") {
        println!("{}", lint_json(&findings, files.len()));
    } else {
        let fix_plan = args.has("fix-plan");
        for f in &findings {
            println!("{}", f.render());
            if fix_plan {
                println!("    fix: {}", f.fix);
            }
        }
        println!("lint: {} finding(s) across {} file(s)", findings.len(), files.len());
    }
    if findings.is_empty() {
        Ok(())
    } else {
        Err(format!("{} lint finding(s)", findings.len()))
    }
}

/// The `esda lint --json` document: the counts CI trends plus one
/// object per finding (empty array on a clean tree).
fn lint_json(findings: &[esda::lint::Finding], n_files: usize) -> String {
    use esda::util::json::Json;
    let arr = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("rule", Json::Str(f.rule.to_string())),
                ("message", Json::Str(f.message.clone())),
                ("fix", Json::Str(f.fix.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("files_scanned", Json::Num(n_files as f64)),
        ("findings", Json::Arr(arr)),
        ("count", Json::Num(findings.len() as f64)),
    ])
    .to_string()
}
