//! Power and energy model, calibrated against the paper's Table 1.
//!
//! The board power in Table 1 spans 1.40–2.10 W across eight designs whose
//! resource footprints are published (DSP/BRAM/FF/LUT at 187 MHz). We fit
//!
//! ```text
//! P [W] = β₀ + β₁·DSP + β₂·BRAM + β₃·(FF+LUT)
//! ```
//!
//! by ordinary least squares on those eight rows, and use the fitted
//! coefficients to assign power to our own configurations. Energy per
//! inference = P × latency. This is the standard analytic substitute when
//! no board is available; the *relative* ordering across designs is the
//! reproduced quantity (DESIGN.md §8).

use super::cost::Resources;
use crate::util::stats::ols;

/// One published row: (dsp, bram, ff, lut, watts).
pub const TABLE1_ROWS: &[(f64, f64, f64, f64, f64)] = &[
    // ESDA rows of Table 1 (FF/LUT in thousands in the paper; absolute here).
    (1792.0, 1278.0, 115_000.0, 154_000.0, 1.81), // N-Caltech101 ESDA-Net
    (1992.0, 1600.0, 198_000.0, 207_000.0, 2.10), // N-Caltech101 MobileNetV2
    (1532.0, 848.0, 97_000.0, 128_000.0, 1.58),   // DvsGesture ESDA-Net
    (1636.0, 1134.0, 104_000.0, 140_000.0, 1.73), // DvsGesture MobileNetV2
    (1494.0, 917.0, 97_000.0, 131_000.0, 1.60),   // ASL-DVS ESDA-Net
    (1416.0, 1069.0, 108_000.0, 144_000.0, 1.75), // ASL-DVS MobileNetV2
    (1525.0, 978.0, 93_000.0, 121_000.0, 1.55),   // N-MNIST ESDA-Net
    (1282.0, 765.0, 72_000.0, 95_000.0, 1.40),    // RoShamBo17 ESDA-Net
];

/// Fitted power model.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// [β₀, β₁ (W/DSP), β₂ (W/BRAM), β₃ (W/(FF+LUT))]
    pub beta: Vec<f64>,
    /// RMS residual of the fit over the Table 1 rows (W).
    pub rms_residual: f64,
}

impl PowerModel {
    /// Fit to the Table 1 rows.
    pub fn calibrated() -> PowerModel {
        let xs: Vec<Vec<f64>> = TABLE1_ROWS
            .iter()
            .map(|&(d, b, ff, lut, _)| vec![1.0, d, b, ff + lut])
            .collect();
        let y: Vec<f64> = TABLE1_ROWS.iter().map(|&(_, _, _, _, w)| w).collect();
        let beta = ols(&xs, &y).expect("power fit is well-conditioned");
        let rms = (xs
            .iter()
            .zip(&y)
            .map(|(row, &w)| {
                let p: f64 = row.iter().zip(&beta).map(|(x, b)| x * b).sum();
                (p - w) * (p - w)
            })
            .sum::<f64>()
            / y.len() as f64)
            .sqrt();
        PowerModel { beta, rms_residual: rms }
    }

    /// Predicted board power for a resource footprint.
    pub fn watts(&self, r: &Resources) -> f64 {
        let x = [1.0, r.dsp as f64, r.bram as f64, (r.ff + r.lut) as f64];
        x.iter().zip(&self.beta).map(|(a, b)| a * b).sum::<f64>().max(0.5)
    }

    /// Energy per inference in millijoules at `clock_hz`.
    pub fn energy_mj(&self, r: &Resources, cycles: f64, clock_hz: f64) -> f64 {
        self.watts(r) * (cycles / clock_hz) * 1e3
    }
}

/// The paper's PL clock.
pub const CLOCK_HZ: f64 = 187e6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_table1_within_tolerance() {
        let m = PowerModel::calibrated();
        assert!(m.rms_residual < 0.15, "rms {}", m.rms_residual);
        for &(d, b, ff, lut, w) in TABLE1_ROWS {
            let p = m.watts(&Resources {
                dsp: d as usize,
                bram: b as usize,
                ff: ff as usize,
                lut: lut as usize,
            });
            assert!((p - w).abs() < 0.35, "predicted {p} vs published {w}");
        }
    }

    #[test]
    fn power_monotone_in_resources() {
        let m = PowerModel::calibrated();
        let small = Resources { dsp: 500, bram: 300, ff: 40_000, lut: 60_000 };
        let large = Resources { dsp: 2000, bram: 1500, ff: 180_000, lut: 200_000 };
        assert!(m.watts(&large) > m.watts(&small));
    }

    #[test]
    fn energy_example_in_paper_range() {
        // DvsGesture ESDA-Net: 0.66 ms at 1.58 W ⇒ ~1.04 mJ (paper: 1.03).
        let m = PowerModel::calibrated();
        let r = Resources { dsp: 1532, bram: 848, ff: 97_000, lut: 128_000 };
        let cycles = 0.66e-3 * CLOCK_HZ;
        let e = m.energy_mj(&r, cycles, CLOCK_HZ);
        assert!((e - 1.03).abs() < 0.3, "energy {e} mJ");
    }
}
