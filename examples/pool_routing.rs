// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Heterogeneous-pool routing demo: differently-shaped replica classes
//! coexist behind one serving runtime (the paper's composability story,
//! Ev-Edge-style), and the cost-aware router learns where requests
//! complete fastest.
//!
//! Two runs:
//! 1. `func=2,sim=1` — a fast functional class (batch affinity 4) and a
//!    cycle-accurate simulator class (batch 1) share traffic; the router
//!    probes both to seed their cost models, then shifts the stream
//!    toward the cheaper class while the simulator keeps contributing
//!    hardware cycle numbers for the requests it serves.
//! 2. a fast functional class vs a deliberately slow one — once the slow
//!    class's EWMA seeds, the router measurably starves it.
//!
//! Run: `cargo run --release --example pool_routing -- --dataset n_mnist --requests 96`

use esda::arch::HwConfig;
use esda::coordinator::{
    run_pool, Backend, BackendError, Classification, Functional, ReplicaPool, ReplicaSpec,
    ServerConfig, ServerResult,
};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::stats::fmt_secs;
use esda::util::Rng;

/// A deliberately slow backend so the router has something to avoid.
struct Throttled {
    inner: Functional,
    delay: std::time::Duration,
}

impl Backend for Throttled {
    fn name(&self) -> &str {
        "throttled-functional"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
}

fn report(label: &str, r: &ServerResult) {
    let m = &r.metrics;
    let e2e = m.e2e_percentiles();
    println!("== {label} ==");
    println!(
        "  {} served ({} dropped) | e2e p50 {} p95 {} | {:.0} req/s",
        m.total,
        m.dropped,
        fmt_secs(e2e.p50),
        fmt_secs(e2e.p95),
        m.throughput(),
    );
    println!("{}", esda::report::pool_table(m).render());
    if let Some(ms) = m.mean_sim_latency_ms(esda::hwopt::power::CLOCK_HZ) {
        println!("  simulated hardware latency: {ms:.3} ms/inf @187 MHz (sim-served share)");
    }
    println!();
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]).unwrap();
    let name = args.get_or("dataset", "n_mnist");
    let n_requests = args.get_usize("requests", 96).unwrap();
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);
    let n_ops = spec.ops().len();

    let cfg = ServerConfig { n_requests, seed: 3, queue_depth: 8, ..Default::default() };

    // 1: composed platforms — functional replicas next to the cycle
    // simulator, each at its own batch affinity.
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::functional(2, qnet.clone()),
        ReplicaSpec::simulator(1, qnet.clone(), HwConfig::uniform(n_ops, 16)),
    ])
    .expect("pool build");
    let r = run_pool(&profile, &pool, &cfg).expect("pool serve");
    report("func=2 (batch 4) + sim=1 (batch 1), cost-aware routing", &r);

    // 2: the router learns to starve a slow class.
    let slow_qnet = qnet.clone();
    let pool = ReplicaPool::build(vec![
        ReplicaSpec::functional(1, qnet),
        ReplicaSpec::new("slow", 1, 1, move |_| {
            Ok(Box::new(Throttled {
                inner: Functional::new(slow_qnet.clone()),
                delay: std::time::Duration::from_millis(5),
            }))
        }),
    ])
    .expect("pool build");
    let r = run_pool(&profile, &pool, &cfg).expect("pool serve");
    report("fast func=1 vs slow(5 ms)=1 — routing shifts load off the slow class", &r);
    for c in &r.metrics.per_class {
        println!(
            "  class {:<6} served {:>4} of {} ({} probe(s) before its cost model seeded)",
            c.class, c.served, r.metrics.total, c.unseeded
        );
    }
}
