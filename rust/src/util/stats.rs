//! Summary statistics and wall-clock timing helpers for the bench harness
//! (criterion is not vendored; `rust/benches/*` use `harness = false` and
//! these utilities).

use std::time::Instant;

/// Streaming summary of a sample set.
///
/// Non-finite samples (NaN, ±∞) are **dropped on entry**: they carry no
/// usable ordering or magnitude information — a single NaN used to panic
/// the sort's `partial_cmp().unwrap()`, and an infinity poisons every
/// mean/percentile it touches. Summarizing the finite subset keeps every
/// statistic well-defined; callers that must treat non-finite input as an
/// error should validate before pushing ([`Summary::n`] reflects only the
/// samples actually kept).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { xs: Vec::new() }
    }
    pub fn from(xs: &[f64]) -> Self {
        let mut s = Summary { xs: xs.iter().copied().filter(|x| x.is_finite()).collect() };
        // total_cmp: total order even if a non-finite ever slips through.
        s.xs.sort_by(f64::total_cmp);
        s
    }
    pub fn push(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let pos = self.xs.partition_point(|&v| v < x);
        self.xs.insert(pos, x);
    }
    pub fn n(&self) -> usize {
        self.xs.len()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }
    pub fn min(&self) -> f64 {
        self.xs.first().copied().unwrap_or(f64::NAN)
    }
    pub fn max(&self) -> f64 {
        self.xs.last().copied().unwrap_or(f64::NAN)
    }
    /// Interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let rank = (q / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Time a closure over `warmup + iters` runs; returns per-iteration seconds
/// as a [`Summary`] over the measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".to_string();
    }
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Ordinary least squares fit `y ≈ X·beta` via normal equations with
/// Gaussian elimination. Used by the power-model calibration
/// (`hwopt::power`). Returns beta of length `X[0].len()`.
///
/// Returns `None` for degenerate systems — including any non-finite
/// entry in `X` or `y` (a NaN sample used to panic the pivot search's
/// `partial_cmp().unwrap()`, and would otherwise propagate NaN into
/// every coefficient) and ragged rows.
pub fn ols(x_rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = x_rows.len();
    if n == 0 || n != y.len() {
        return None;
    }
    let k = x_rows[0].len();
    if x_rows.iter().any(|r| r.len() != k || r.iter().any(|v| !v.is_finite()))
        || y.iter().any(|v| !v.is_finite())
    {
        return None;
    }
    // Normal equations: (XᵀX) beta = Xᵀy
    let mut a = vec![vec![0.0f64; k + 1]; k]; // augmented
    for r in 0..k {
        for c in 0..k {
            a[r][c] = x_rows.iter().map(|row| row[r] * row[c]).sum();
        }
        a[r][k] = x_rows.iter().zip(y).map(|(row, &yy)| row[r] * yy).sum();
    }
    // Gaussian elimination with partial pivoting (total_cmp: immune to
    // any NaN that arithmetic might still manufacture).
    for col in 0..k {
        let piv = (col..k).max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))?;
        let p = a[piv][col].abs();
        if p.is_nan() || p < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        let d = a[col][col];
        for c in col..=k {
            a[col][c] /= d;
        }
        for r in 0..k {
            if r != col {
                let factor = a[r][col];
                for c in col..=k {
                    a[r][c] -= factor * a[col][c];
                }
            }
        }
    }
    Some((0..k).map(|r| a[r][k]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[3.0, 1.0, 2.0, 4.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.mean(), 2.5);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn push_keeps_sorted() {
        let mut s = Summary::new();
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.median(), 3.0);
    }

    /// Regression: non-finite samples used to panic `Summary::from`'s
    /// `partial_cmp().unwrap()` sort. They are dropped instead, and every
    /// statistic stays well-defined over the finite subset.
    #[test]
    fn summary_drops_non_finite_samples() {
        let s = Summary::from(&[f64::NAN, 1.0]);
        assert_eq!(s.n(), 1);
        assert_eq!(s.mean(), 1.0);
        assert_eq!(s.median(), 1.0);
        let s = Summary::from(&[f64::INFINITY, 2.0, f64::NEG_INFINITY, 4.0, f64::NAN]);
        assert_eq!(s.n(), 2);
        assert_eq!((s.min(), s.max()), (2.0, 4.0));
        assert_eq!(s.mean(), 3.0);
        // push applies the same policy (a NaN used to land unsorted at
        // the front and corrupt every later percentile).
        let mut s = Summary::new();
        s.push(f64::NAN);
        s.push(3.0);
        s.push(f64::INFINITY);
        assert_eq!(s.n(), 1);
        assert_eq!(s.median(), 3.0);
        // All-non-finite input degrades to the explicit empty summary.
        let s = Summary::from(&[f64::NAN, f64::NAN]);
        assert_eq!(s.n(), 0);
        assert!(s.mean().is_nan() && s.percentile(50.0).is_nan());
    }

    #[test]
    fn ols_recovers_plane() {
        // y = 2*a + 3*b + 1 (intercept as constant column)
        let xs: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![1.0, i as f64, (i * i % 7) as f64])
            .collect();
        let y: Vec<f64> = xs.iter().map(|r| 1.0 + 2.0 * r[1] + 3.0 * r[2]).collect();
        let beta = ols(&xs, &y).unwrap();
        assert!((beta[0] - 1.0).abs() < 1e-8);
        assert!((beta[1] - 2.0).abs() < 1e-8);
        assert!((beta[2] - 3.0).abs() < 1e-8);
    }

    /// Regression: a NaN anywhere in the design matrix or targets used to
    /// panic the pivot search; it now reports the system as degenerate.
    #[test]
    fn ols_rejects_non_finite_inputs() {
        let mut xs: Vec<Vec<f64>> =
            (0..6).map(|i| vec![1.0, i as f64, (2 * i) as f64 % 5.0]).collect();
        let y: Vec<f64> = xs.iter().map(|r| r[1] + r[2]).collect();
        assert!(ols(&xs, &y).is_some(), "finite baseline must fit");
        xs[2][1] = f64::NAN;
        assert_eq!(ols(&xs, &y), None, "NaN row must not panic or fit");
        xs[2][1] = f64::INFINITY;
        assert_eq!(ols(&xs, &y), None);
        xs[2][1] = 2.0;
        let mut y_bad = y.clone();
        y_bad[4] = f64::NAN;
        assert_eq!(ols(&xs, &y_bad), None, "NaN target must not panic or fit");
        // Ragged rows are degenerate too, not an index panic.
        let mut ragged = xs;
        ragged[1] = vec![1.0];
        assert_eq!(ols(&ragged, &y), None);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(2e-9).contains("ns"));
        assert!(fmt_secs(2e-6).contains("µs"));
        assert!(fmt_secs(2e-3).contains("ms"));
        assert!(fmt_secs(2.0).ends_with("s"));
    }
}
