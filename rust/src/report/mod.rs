//! Paper-style table/figure rendering used by the benches and the CLI.

use std::fmt::Write as _;

/// A simple aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{:<width$}", c, width = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let sep = "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1));
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a ratio as "N.N×".
pub fn speedup(v: f64) -> String {
    format!("{v:.1}×")
}

/// A named (x, y) series — the text rendering of a figure.
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render figure series as aligned columns (x then one column per series).
pub fn render_series(title: &str, xlabel: &str, series: &[Series]) -> String {
    let mut t = Table::new(
        title,
        &std::iter::once(xlabel)
            .chain(series.iter().map(|s| s.name.as_str()))
            .collect::<Vec<_>>(),
    );
    if let Some(first) = series.first() {
        for (i, &(x, _)) in first.points.iter().enumerate() {
            let mut row = vec![f(x, 2)];
            for s in series {
                row.push(f(s.points.get(i).map(|p| p.1).unwrap_or(f64::NAN), 3));
            }
            t.row(row);
        }
    }
    t.render()
}

/// Render the serving runtime's per-worker utilization/latency breakdown
/// plus the aggregate row (used by `esda serve` and the serving example).
pub fn serving_table(m: &crate::coordinator::Metrics) -> Table {
    use crate::util::stats::fmt_secs;
    let wall_s = m.wall_seconds();
    let mut t = Table::new(
        "serving — per-worker breakdown",
        &[
            "worker", "class", "served", "visits", "util", "svc p50", "svc p99", "e2e p50",
            "e2e p95", "e2e p99",
        ],
    );
    for w in &m.per_worker {
        t.row(vec![
            format!("#{}", w.worker),
            if w.class.is_empty() { "-".to_string() } else { w.class.clone() },
            w.served.to_string(),
            w.batches.to_string(),
            format!("{:.0}%", w.utilization(wall_s) * 100.0),
            fmt_secs(w.service.p50),
            fmt_secs(w.service.p99),
            fmt_secs(w.e2e.p50),
            fmt_secs(w.e2e.p95),
            fmt_secs(w.e2e.p99),
        ]);
    }
    let e2e = m.e2e_percentiles();
    let svc = m.service_percentiles();
    let mean_util = if m.per_worker.is_empty() {
        f64::NAN
    } else {
        m.per_worker.iter().map(|w| w.utilization(wall_s)).sum::<f64>()
            / m.per_worker.len() as f64
    };
    t.row(vec![
        "all".to_string(),
        "-".to_string(),
        m.total.to_string(),
        m.batch_sizes.len().to_string(),
        format!("{:.0}%", mean_util * 100.0),
        fmt_secs(svc.p50),
        fmt_secs(svc.p99),
        fmt_secs(e2e.p50),
        fmt_secs(e2e.p95),
        fmt_secs(e2e.p99),
    ]);
    t
}

/// Render the heterogeneous pool's per-class breakdown: traffic share,
/// realized batch shape, utilization, how well the routing cost model
/// predicted observed service times, and per-class deadline sheds (used
/// by `esda serve --pool` and the routing example).
pub fn pool_table(m: &crate::coordinator::Metrics) -> Table {
    use crate::util::stats::fmt_secs;
    let wall_s = m.wall_seconds();
    let mut t = Table::new(
        "serving — per-class breakdown (cost-aware routing)",
        &[
            "class", "replicas", "served", "share", "visits", "mean batch", "util", "svc p50",
            "svc p99", "cost err", "probes", "ddl drops",
        ],
    );
    // NaN marks "no data" (class never served / never predicted-for):
    // render it as a dash, not a literal NaN, in the user-facing table.
    let pct = |v: f64| if v.is_finite() { format!("{:.0}%", v * 100.0) } else { "-".into() };
    for c in &m.per_class {
        let share = if m.total == 0 { f64::NAN } else { c.served as f64 / m.total as f64 };
        let mean_batch =
            if c.batches == 0 { f64::NAN } else { c.served as f64 / c.batches as f64 };
        // A fixed class renders its count; an autoscaled one renders the
        // final count, the configured band, and the peak it reached.
        let replicas = if c.replicas_max > c.replicas_min {
            format!(
                "{} [{}..{}] peak {}",
                c.replicas, c.replicas_min, c.replicas_max, c.replicas_peak
            )
        } else {
            c.replicas.to_string()
        };
        t.row(vec![
            c.class.clone(),
            replicas,
            c.served.to_string(),
            pct(share),
            c.batches.to_string(),
            if mean_batch.is_finite() { format!("{mean_batch:.2}") } else { "-".into() },
            pct(c.utilization(wall_s)),
            fmt_secs(c.service.p50),
            fmt_secs(c.service.p99),
            pct(c.cost_err),
            c.unseeded.to_string(),
            c.deadline_drops.to_string(),
        ]);
    }
    t
}

/// Render the multi-tenant front door's per-tenant breakdown: configured
/// weight and the ingress quota it earned, served/dropped/deadline-shed
/// counts, recoverable ingest rejects, the conservation total
/// ([`TenantStats::offered`]), and per-tenant SLO attainment (used by
/// `esda serve --tenant` and the net-serving example).
///
/// [`TenantStats::offered`]: crate::coordinator::TenantStats::offered
pub fn tenant_table(m: &crate::coordinator::Metrics) -> Table {
    let mut t = Table::new(
        "serving — per-tenant front door",
        &[
            "tenant", "weight", "quota", "served", "dropped", "ddl drops", "rejects", "offered",
            "slo",
        ],
    );
    // A tenant that was never offered a deadline renders a dash, not NaN.
    let pct = |v: f64| if v.is_finite() { format!("{:.1}%", v * 100.0) } else { "-".into() };
    for ts in &m.per_tenant {
        t.row(vec![
            ts.tenant.clone(),
            ts.weight.to_string(),
            ts.quota.to_string(),
            ts.served.to_string(),
            ts.dropped.to_string(),
            ts.deadline_drops().to_string(),
            ts.ingest_rejects.to_string(),
            ts.offered().to_string(),
            ts.slo_attainment().map(pct).unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// Render the fleet's per-model breakdown: classes serving each model,
/// served volume with accuracy (rate plus raw correct count), admission
/// drops, the deadline-shed split, and the conservation total
/// ([`ModelStats::offered`] — each model's books must reconstruct its
/// offered load independently). Used by `esda serve --model` and the
/// fleet-serving example; single-model runs render one `default` row
/// restating the global books.
///
/// [`ModelStats::offered`]: crate::coordinator::ModelStats::offered
pub fn model_table(m: &crate::coordinator::Metrics) -> Table {
    let mut t = Table::new(
        "serving — per-model fleet",
        &[
            "model", "classes", "served", "accuracy", "dropped", "ddl offered", "ddl in/rt",
            "offered",
        ],
    );
    for ms in &m.per_model {
        t.row(vec![
            ms.model.clone(),
            ms.classes.to_string(),
            ms.served.to_string(),
            // A model that served nothing makes no accuracy claim.
            ms.accuracy()
                .map(|a| format!("{:.1}% ({}/{})", a * 100.0, ms.correct, ms.served))
                .unwrap_or_else(|| "-".into()),
            ms.dropped.to_string(),
            ms.deadline_offered.to_string(),
            format!("{} + {}", ms.deadline_ingress, ms.deadline_router),
            ms.offered().to_string(),
        ]);
    }
    t
}

/// One-line shadow-conformance summary — per shadowed model: mirrored
/// volume, disagreement count and rate, and how many disagreements the
/// capture file could not hold. `None` when no model mirrored anything
/// (no `--shadow`, or the shadowed model saw no traffic).
pub fn shadow_line(m: &crate::coordinator::Metrics) -> Option<String> {
    let parts: Vec<String> = m
        .per_model
        .iter()
        .filter(|ms| ms.shadow_mirrored > 0)
        .map(|ms| {
            let rate = ms
                .disagreement_rate()
                .map(|r| format!("{:.1}%", r * 100.0))
                .unwrap_or_else(|| "-".into());
            format!(
                "{}: {} mirrored, {} disagreement(s) ({rate}), {} capture drop(s)",
                ms.model, ms.shadow_mirrored, ms.shadow_disagreements, ms.shadow_capture_drops,
            )
        })
        .collect();
    if parts.is_empty() {
        return None;
    }
    Some(format!("shadow conformance: {}", parts.join(" | ")))
}

/// The serving headline: volumes, accuracy (rate plus the raw correct
/// count — the rate alone hides how thin the sample is), end-to-end and
/// service latency percentiles, throughput, and worker count.
pub fn summary_line(m: &crate::coordinator::Metrics) -> String {
    let e2e = m.e2e_percentiles();
    let svc = m.service_percentiles();
    format!(
        "{} served / {} offered ({} dropped, {:.1}% drop rate) | accuracy {:.2} \
         ({}/{} correct) | e2e p50 {} p95 {} p99 {} | svc p50 {} | {:.0} req/s | {} worker(s)",
        m.total,
        m.offered(),
        m.dropped,
        m.drop_rate() * 100.0,
        m.accuracy(),
        m.correct,
        m.total,
        crate::util::stats::fmt_secs(e2e.p50),
        crate::util::stats::fmt_secs(e2e.p95),
        crate::util::stats::fmt_secs(e2e.p99),
        crate::util::stats::fmt_secs(svc.p50),
        m.throughput(),
        m.per_worker.len(),
    )
}

/// One-line SLO summary — attainment over every *offered* deadline
/// (sheds and drops count as misses), the served-only figure beside it,
/// and the deadline-drop breakdown (ingress expiries vs
/// router/scheduling sheds), kept distinct from queue-full drops. `None`
/// when the run carried no deadlines.
pub fn slo_line(m: &crate::coordinator::Metrics) -> Option<String> {
    let attainment = m.slo_attainment()?;
    let served_only = match m.slo_attainment_served() {
        Some(v) => format!("{:.1}% of served", v * 100.0),
        None => "none served".to_string(),
    };
    Some(format!(
        "SLO attainment {:.1}% ({} of {} offered in deadline; {served_only}; {} served \
         late) | deadline drops: {} ingress + {} router | {} queue-full drop(s)",
        attainment * 100.0,
        m.deadline_met,
        m.deadline_offered,
        m.deadline_missed,
        m.deadline_ingress,
        m.deadline_router,
        m.dropped,
    ))
}

/// One-line delta-inference summary — hit rate over delta attempts, the
/// mean dirty/recomputed site fractions on hits, the full-recompute
/// fallback breakdown, and the router's sticky-delivery books. `None`
/// when no delta-capable backend served (nothing to report). NaN means
/// (zero hits) render as dashes, never literal NaNs.
pub fn delta_line(m: &crate::coordinator::Metrics) -> Option<String> {
    let d = &m.delta;
    if d.attempts() == 0 {
        return None;
    }
    let pct = |v: f64| if v.is_finite() { format!("{:.1}%", v * 100.0) } else { "-".into() };
    Some(format!(
        "delta inference: {} hit(s) / {} attempt(s) ({}; dirty {}, recomputed {}) | full \
         recompute: {} cold + {} geometry + {} over-threshold | {} outside delta scope | \
         sticky: {} hit(s), miss {} cold + {} retired + {} capacity",
        d.hits,
        d.attempts(),
        pct(d.hit_rate()),
        pct(d.mean_dirty_frac()),
        pct(d.mean_recomputed_frac()),
        d.full_cold,
        d.full_geometry,
        d.full_over_threshold,
        d.not_applicable,
        d.sticky_hits,
        d.sticky_cold,
        d.sticky_retired,
        d.sticky_capacity,
    ))
}

/// The autoscaler's decision log, one line per scaling event (empty when
/// the run had no autoscaler or it never acted).
pub fn scaling_log(m: &crate::coordinator::Metrics) -> Vec<String> {
    m.scaling_events
        .iter()
        .map(|e| {
            format!(
                "[+{:.3}s] {}: {} -> {} replica(s) ({})",
                e.at_s, e.class, e.from, e.to, e.reason
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn serving_table_renders() {
        use crate::coordinator::{Metrics, PercentileReport, RequestTiming, WorkerStats};
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.002, service_s: 0.001, sim_cycles: None }, true);
        m.per_worker.push(WorkerStats {
            worker: 0,
            served: 1,
            busy_s: 0.001,
            service: PercentileReport::from_samples(&[0.001]),
            e2e: PercentileReport::from_samples(&[0.002]),
            ..Default::default()
        });
        let s = serving_table(&m).render();
        assert!(s.contains("#0"), "{s}");
        assert!(s.contains("all"), "{s}");
    }

    #[test]
    fn pool_table_renders_class_rows() {
        use crate::coordinator::{ClassStats, Metrics, PercentileReport, RequestTiming};
        let mut m = Metrics::default();
        m.record(RequestTiming { e2e_s: 0.002, service_s: 0.001, sim_cycles: None }, true);
        m.record(RequestTiming { e2e_s: 0.004, service_s: 0.002, sim_cycles: None }, true);
        m.per_class.push(ClassStats {
            class: "func".into(),
            replicas: 2,
            replicas_min: 1,
            replicas_max: 4,
            replicas_peak: 3,
            replica_s: 0.006,
            served: 2,
            batches: 1,
            busy_s: 0.003,
            batch: PercentileReport::from_samples(&[2.0]),
            service: PercentileReport::from_samples(&[0.001, 0.002]),
            cost_err: 0.25,
            unseeded: 1,
            deadline_drops: 3,
        });
        m.per_class.push(ClassStats {
            class: "sim".into(),
            replicas: 1,
            replicas_min: 1,
            replicas_max: 1,
            replicas_peak: 1,
            replica_s: 0.0,
            served: 0,
            batches: 0,
            busy_s: 0.0,
            batch: PercentileReport::default(),
            service: PercentileReport::default(),
            cost_err: f64::NAN,
            unseeded: 0,
            deadline_drops: 0,
        });
        let s = pool_table(&m).render();
        assert!(s.contains("func"), "{s}");
        assert!(s.contains("sim"), "{s}");
        assert!(s.contains("100%"), "func serves the full stream: {s}");
        assert!(s.contains("ddl drops"), "per-class deadline sheds must render: {s}");
        // The autoscaled class renders its band and peak; the fixed class
        // renders a bare count.
        assert!(s.contains("2 [1..4] peak 3"), "{s}");
        // The zero-traffic class renders dashes, never a literal NaN.
        assert!(!s.contains("NaN"), "{s}");
    }

    /// The tenant table renders one row per tenant, dashes (never NaN)
    /// for tenants that carried no deadlines, and the conservation
    /// total in the "offered" column.
    #[test]
    fn tenant_table_renders_per_tenant_rows() {
        use crate::coordinator::{Metrics, TenantStats};
        let mut m = Metrics::default();
        m.per_tenant.push(TenantStats {
            tenant: "cam-a".into(),
            weight: 3,
            quota: 12,
            served: 40,
            dropped: 2,
            deadline_offered: 40,
            deadline_met: 39,
            deadline_missed: 1,
            ingest_rejects: 1,
            ..Default::default()
        });
        m.per_tenant.push(TenantStats {
            tenant: "cam-b".into(),
            weight: 1,
            quota: 4,
            served: 5,
            ..Default::default()
        });
        let s = tenant_table(&m).render();
        assert!(s.contains("cam-a"), "{s}");
        assert!(s.contains("cam-b"), "{s}");
        assert!(s.contains("97.5%"), "attainment 39/40: {s}");
        assert!(s.contains("43"), "offered = 40 + 2 + 0 + 1: {s}");
        assert!(!s.contains("NaN"), "no-deadline tenant renders a dash: {s}");
    }

    /// The model table renders one row per model with its conservation
    /// total, and a dash (never NaN) for a model that served nothing.
    #[test]
    fn model_table_renders_per_model_rows() {
        use crate::coordinator::{Metrics, ModelStats};
        let mut m = Metrics::default();
        m.per_model.push(ModelStats {
            model: "alpha".into(),
            classes: 2,
            served: 8,
            correct: 6,
            dropped: 1,
            deadline_offered: 8,
            deadline_ingress: 1,
            deadline_router: 2,
            ..Default::default()
        });
        m.per_model.push(ModelStats { model: "beta".into(), classes: 1, ..Default::default() });
        let s = model_table(&m).render();
        assert!(s.contains("alpha"), "{s}");
        assert!(s.contains("75.0% (6/8)"), "accuracy rate + raw count: {s}");
        assert!(s.contains("12"), "offered = 8 + 1 + 3: {s}");
        assert!(s.contains("1 + 2"), "deadline split: {s}");
        assert!(!s.contains("NaN"), "zero-traffic model renders a dash: {s}");
    }

    /// The shadow line is absent without mirrored traffic and renders
    /// the per-model disagreement books when there is.
    #[test]
    fn shadow_line_renders_disagreement_books() {
        use crate::coordinator::{Metrics, ModelStats};
        let mut m = Metrics::default();
        assert_eq!(shadow_line(&m), None, "no per-model books ⇒ no line");
        m.per_model.push(ModelStats { model: "alpha".into(), served: 10, ..Default::default() });
        assert_eq!(shadow_line(&m), None, "no mirrored traffic ⇒ no line");
        m.per_model.push(ModelStats {
            model: "beta".into(),
            served: 10,
            shadow_mirrored: 8,
            shadow_disagreements: 2,
            shadow_capture_drops: 1,
            ..Default::default()
        });
        let line = shadow_line(&m).unwrap();
        assert!(line.contains("beta: 8 mirrored"), "{line}");
        assert!(line.contains("2 disagreement(s) (25.0%)"), "{line}");
        assert!(line.contains("1 capture drop(s)"), "{line}");
        assert!(!line.contains("alpha"), "unshadowed models stay off the line: {line}");
    }

    /// The scaling log renders one line per autoscaler decision.
    #[test]
    fn scaling_log_renders_events() {
        use crate::coordinator::{Metrics, ScalingEvent};
        let mut m = Metrics::default();
        assert!(scaling_log(&m).is_empty(), "no autoscaler ⇒ no log");
        m.scaling_events.push(ScalingEvent {
            at_s: 0.25,
            class: "func".into(),
            from: 1,
            to: 2,
            reason: "deadline-drop rate 3.0/s in window".into(),
        });
        m.scaling_events.push(ScalingEvent {
            at_s: 1.5,
            class: "func".into(),
            from: 2,
            to: 1,
            reason: "idle: backlog 0, util 4% < 20%".into(),
        });
        let lines = scaling_log(&m);
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("func: 1 -> 2"), "{}", lines[0]);
        assert!(lines[0].contains("deadline-drop rate"), "{}", lines[0]);
        assert!(lines[1].contains("2 -> 1"), "{}", lines[1]);
    }

    /// The SLO line distinguishes deadline drops from queue-full drops
    /// and is absent when no deadlines were configured.
    #[test]
    fn slo_line_renders_the_deadline_breakdown() {
        use crate::coordinator::Metrics;
        let mut m = Metrics::default();
        assert_eq!(slo_line(&m), None, "no SLO ⇒ no line");
        m.deadline_offered = 10;
        m.deadline_met = 6;
        m.deadline_missed = 1;
        m.deadline_ingress = 1;
        m.deadline_router = 2;
        m.dropped = 0;
        let line = slo_line(&m).unwrap();
        assert!(line.contains("60.0%"), "{line}");
        assert!(line.contains("85.7% of served"), "served-only figure: {line}");
        assert!(line.contains("1 ingress"), "{line}");
        assert!(line.contains("2 router"), "{line}");
        assert!(line.contains("0 queue-full"), "{line}");
    }

    /// The headline carries the raw correct count beside the accuracy
    /// rate, so a thin sample can't hide behind a flattering percentage.
    #[test]
    fn summary_line_reports_the_raw_correct_count() {
        use crate::coordinator::Metrics;
        let mut m = Metrics::default();
        m.correct = 3;
        m.total = 4;
        let line = summary_line(&m);
        assert!(line.contains("4 served"), "{line}");
        assert!(line.contains("(3/4 correct)"), "{line}");
    }

    /// The delta line is absent without delta traffic, renders the
    /// hit/fallback/sticky breakdown when there is, and never shows a
    /// literal NaN even with zero hits.
    #[test]
    fn delta_line_renders_the_hit_and_fallback_breakdown() {
        use crate::coordinator::Metrics;
        let mut m = Metrics::default();
        assert_eq!(delta_line(&m), None, "no delta traffic ⇒ no line");
        m.delta.hits = 8;
        m.delta.full_cold = 2;
        m.delta.full_over_threshold = 1;
        m.delta.dirty_frac_sum = 0.8;
        m.delta.recomputed_frac_sum = 1.6;
        m.delta.sticky_hits = 7;
        m.delta.sticky_cold = 2;
        m.delta.sticky_retired = 1;
        let line = delta_line(&m).unwrap();
        assert!(line.contains("8 hit(s) / 11 attempt(s)"), "{line}");
        assert!(line.contains("72.7%"), "hit rate: {line}");
        assert!(line.contains("dirty 10.0%"), "{line}");
        assert!(line.contains("recomputed 20.0%"), "{line}");
        assert!(line.contains("2 cold + 0 geometry + 1 over-threshold"), "{line}");
        assert!(line.contains("0 outside delta scope"), "{line}");
        assert!(line.contains("sticky: 7 hit(s)"), "{line}");
        // All-fallback runs (zero hits) render dashes, never NaN.
        let mut m2 = Metrics::default();
        m2.delta.full_cold = 3;
        let line2 = delta_line(&m2).unwrap();
        assert!(!line2.contains("NaN"), "{line2}");
        assert!(line2.contains("dirty -"), "{line2}");
    }

    #[test]
    fn series_render() {
        let s = render_series(
            "Fig",
            "nz",
            &[Series { name: "sparse".into(), points: vec![(0.1, 4.5), (0.5, 1.9)] }],
        );
        assert!(s.contains("4.500"));
        assert!(s.contains("0.10"));
    }
}
