//! Paper-style table/figure rendering used by the benches and the CLI.

use std::fmt::Write as _;

/// A simple aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{:<width$}", c, width = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a ratio as "N.N×".
pub fn speedup(v: f64) -> String {
    format!("{v:.1}×")
}

/// A named (x, y) series — the text rendering of a figure.
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render figure series as aligned columns (x then one column per series).
pub fn render_series(title: &str, xlabel: &str, series: &[Series]) -> String {
    let mut t = Table::new(
        title,
        &std::iter::once(xlabel)
            .chain(series.iter().map(|s| s.name.as_str()))
            .collect::<Vec<_>>(),
    );
    if let Some(first) = series.first() {
        for (i, &(x, _)) in first.points.iter().enumerate() {
            let mut row = vec![f(x, 2)];
            for s in series {
                row.push(f(s.points.get(i).map(|p| p.1).unwrap_or(f64::NAN), 3));
            }
            t.row(row);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn series_render() {
        let s = render_series(
            "Fig",
            "nz",
            &[Series { name: "sparse".into(), points: vec![(0.1, 4.5), (0.5, 1.9)] }],
        );
        assert!(s.contains("4.500"));
        assert!(s.contains("0.10"));
    }
}
