// lint:allow-file(panic): fail-fast example binary — unwrap/expect on setup is the idiom
//! Serving demo: the sharded serving runtime (event source →
//! representation builder → admission-controlled ingress queue → a pool of
//! accelerator worker replicas) under sustained load.
//!
//! Three runs show the scaling/admission axes:
//! 1. single replica, lossless (the paper's batch-1 deployment),
//! 2. four replicas, lossless — same predictions, higher throughput,
//! 3. one *slow* replica behind a depth-1 queue with the ESST-style
//!    drop-oldest policy — load shedding with drop accounting.
//!
//! Run: `cargo run --release --example serve_events -- --dataset n_mnist --requests 64`

use esda::arch::HwConfig;
use esda::coordinator::{
    run_server, Backend, BackendError, Classification, DropPolicy, Functional, ServerConfig,
    Simulator,
};
use esda::events::{repr::histogram2_norm, DatasetProfile};
use esda::hwopt::power::CLOCK_HZ;
use esda::model::quant::quantize_network;
use esda::model::weights::FloatWeights;
use esda::model::NetworkSpec;
use esda::sparse::SparseMap;
use esda::util::cli::Args;
use esda::util::stats::fmt_secs;
use esda::util::Rng;

/// A deliberately slow backend to demonstrate saturation + load shedding.
struct Throttled {
    inner: Functional,
    delay: std::time::Duration,
}

impl Backend for Throttled {
    fn name(&self) -> &str {
        "throttled-functional"
    }
    fn classify(&self, map: &SparseMap<f32>) -> Result<Classification, BackendError> {
        std::thread::sleep(self.delay);
        self.inner.classify(map)
    }
}

fn report(label: &str, r: &esda::coordinator::ServerResult) {
    let m = &r.metrics;
    let e2e = m.e2e_percentiles();
    println!("== {label} ==");
    println!(
        "  {} served / {} offered ({} dropped, {:.1}%) | e2e p50 {} p95 {} p99 {} | {:.0} req/s",
        m.total,
        m.offered(),
        m.dropped,
        m.drop_rate() * 100.0,
        fmt_secs(e2e.p50),
        fmt_secs(e2e.p95),
        fmt_secs(e2e.p99),
        m.throughput(),
    );
    println!("{}", esda::report::serving_table(m).render());
    if let Some(ms) = m.mean_sim_latency_ms(CLOCK_HZ) {
        println!("  simulated hardware latency: {ms:.3} ms/inf @187 MHz");
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), &[]).unwrap();
    let name = args.get_or("dataset", "n_mnist");
    let n_requests = args.get_usize("requests", 64).unwrap();
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let spec = NetworkSpec::tiny(profile.w, profile.h, profile.n_classes);
    let weights = FloatWeights::random(&spec, 5);
    let mut rng = Rng::new(11);
    let calib: Vec<_> = (0..4)
        .map(|i| {
            let es = profile.sample(i % profile.n_classes, &mut rng);
            histogram2_norm(&es, profile.w, profile.h, 8.0)
        })
        .collect();
    let qnet = quantize_network(&spec, &weights, &calib);
    let n_ops = spec.ops().len();

    // 1+2: lossless, 1 vs 4 replicas — same prediction multiset.
    let lossless = |workers| ServerConfig {
        n_requests,
        seed: 3,
        clip: 8.0,
        workers,
        queue_depth: 4,
        drop_policy: DropPolicy::Block,
        batch: 1,
        ..Default::default()
    };
    let sim = Simulator::new(qnet.clone(), HwConfig::uniform(n_ops, 16));
    let one = run_server(&profile, &sim, &lossless(1)).expect("serve x1");
    report("cycle simulator, 1 replica (paper's batch-1 deployment)", &one);
    let four = run_server(&profile, &sim, &lossless(4)).expect("serve x4");
    report("cycle simulator, 4 replicas", &four);
    let sorted = |r: &esda::coordinator::ServerResult| {
        let mut v: Vec<(usize, usize)> = r.predictions.iter().map(|p| (p.label, p.pred)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(sorted(&one), sorted(&four), "replication must not change predictions");
    println!(
        "replication check: 1-replica and 4-replica prediction multisets identical \
         ({} requests)\n",
        n_requests
    );

    // 3: saturate a depth-1 queue with a slow replica + drop-oldest.
    let throttled = Throttled {
        inner: Functional::new(qnet),
        delay: std::time::Duration::from_millis(2),
    };
    let shed = ServerConfig {
        n_requests,
        seed: 3,
        clip: 8.0,
        workers: 1,
        queue_depth: 1,
        drop_policy: DropPolicy::DropOldest,
        batch: 1,
        ..Default::default()
    };
    let r = run_server(&profile, &throttled, &shed).expect("serve shedding");
    report("throttled replica, depth-1 queue, drop-oldest admission", &r);
}
