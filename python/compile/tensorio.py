"""Writer/reader for the ESDW tensor container (mirror of
``rust/src/model/weights.rs``)."""

import struct

import numpy as np

MAGIC = 0x4553_4457
VERSION = 1

_DTYPES = {0: np.float32, 1: np.int8, 2: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}


def write_tensors(path, tensors):
    """tensors: dict name → np.ndarray (f32/i8/i32)."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            code = _CODES[arr.dtype]
            f.write(struct.pack("<I", len(name.encode())))
            f.write(name.encode())
            f.write(struct.pack("<B", code))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_tensors(path):
    out = {}
    with open(path, "rb") as f:
        magic, version, n = struct.unpack("<III", f.read(12))
        if magic != MAGIC or version != VERSION:
            raise ValueError(f"bad header in {path}")
        for _ in range(n):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode()
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(_DTYPES[code])
            count = int(np.prod(dims)) if dims else 1
            if ndim == 0:
                count = 1
            data = np.frombuffer(f.read(count * dt.itemsize), dtype=dt).reshape(dims)
            out[name] = data
    return out
