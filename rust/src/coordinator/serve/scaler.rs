//! Stage 5: the autoscaler controller loop — samples per-class backlog
//! and windowed deadline-drop/busy counters every tick, growing a
//! pressured class by building its next replica through the pool's
//! retained factory (spawning a worker for it mid-run) and shrinking an
//! idle class by depositing a retire token.

use super::state::{BackendRef, ClassCtx, SharedCtx, WorkerOutput};
use super::workers::worker_loop;
use super::AutoscaleConfig;
use crate::coordinator::metrics::{ScalingEvent, SlidingWindow};
use crate::coordinator::queue::{AdmissionQueue, DropPolicy};
use crate::util::lockcheck::{RankedCondvar, RankedMutex};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// The autoscaler controller loop: every `auto.interval` it samples each
/// class's backlog plus sliding-window deadline-drop and busy counters,
/// then takes at most one scaling step per class per tick.
///
/// - **Scale up** (pressure): deadline drops landed in the window, or the
///   per-active-replica backlog exceeds the high watermark. The next
///   replica slot's backend is built on demand through the pool's
///   retained factory (and kept warm for later re-activation); a fresh
///   worker thread is spawned into the serving scope for it.
/// - **Scale down** (idle): zero backlog, no deadline drops in the
///   window, and windowed utilization under the low watermark. One
///   retire token is deposited; the first worker of the class to see it
///   drains its in-flight batch and exits.
///
/// A failed scale-up (factory error) is recorded as a scaling event and
/// does not abort serving — the class simply stays at its current size.
/// The controller exits when the spine flips the `stop` latch after the
/// stream has drained.
#[allow(clippy::too_many_arguments)]
pub(super) fn run_autoscaler<'scope, 'env: 'scope, 'a: 'scope>(
    auto: &AutoscaleConfig,
    s: &'scope std::thread::Scope<'scope, '_>,
    sx: &'scope SharedCtx<'env, 'a>,
    has_router: bool,
    t_start: Instant,
    // lint: lock-rank(50): scaler-stop
    stop: &'scope (RankedMutex<bool>, RankedCondvar),
    // lint: lock-rank(41): scaling-events
    scaling_events: &'scope RankedMutex<Vec<ScalingEvent>>,
    // lint: atomic(relaxed): fetch_add id mint — uniqueness needs no order
    next_wid: &'scope AtomicUsize,
    // lint: lock-rank(45): worker-outputs
    outputs_mx: &'scope RankedMutex<Vec<WorkerOutput>>,
    depth: usize,
) {
    let classes = sx.classes;
    let mut drops_w: Vec<SlidingWindow> =
        classes.iter().map(|_| SlidingWindow::new(auto.window)).collect();
    let mut busy_w: Vec<SlidingWindow> =
        classes.iter().map(|_| SlidingWindow::new(auto.window)).collect();
    let push_event = |class: &ClassCtx<'_>, from: usize, to: usize, reason: String| {
        scaling_events.lock().unwrap().push(ScalingEvent {
            at_s: t_start.elapsed().as_secs_f64(),
            class: class.name.clone(),
            from,
            to,
            reason,
        });
    };
    loop {
        // Sleep one tick — or wake immediately when the spine stops us.
        {
            // lint: lock-rank(50): scaler-stop
            let (stop_mx, stop_cv) = stop;
            let mut stopped = stop_mx.lock().unwrap();
            if !*stopped {
                // lint:allow(panic): condvar poisoning is the lock-poisoning
                // idiom — holders never panic while flipping the stop flag
                // lint:allow(lock-span): a condvar wait releases the guard
                // while parked — holding it across the wait is the idiom
                stopped = stop_cv.wait_timeout(stopped, auto.interval).unwrap().0;
            }
            if *stopped {
                return;
            }
        }
        let now = Instant::now();
        for (ci, class) in classes.iter().enumerate() {
            let active = class.active.load(Ordering::SeqCst);
            drops_w[ci].record(now, class.deadline_drops.load(Ordering::Relaxed) as u64);
            busy_w[ci].record(now, class.busy_us.load(Ordering::Relaxed));
            let drop_rate = drops_w[ci].rate();
            let span = busy_w[ci].span_secs();
            let util = if span > 0.0 && active > 0 {
                (busy_w[ci].delta() as f64 / 1e6) / (span * active as f64)
            } else {
                0.0
            };
            // Backlog: the router maintains per-class counts; the
            // routerless single-class path reads the ingress queue.
            let backlog = if has_router {
                class.backlog.load(Ordering::SeqCst)
            } else {
                sx.ingress.stats().2
            };
            let per_replica = backlog as f64 / active.max(1) as f64;
            let pressured = drop_rate > 0.0 || per_replica > auto.high_backlog;
            if pressured && active < class.max {
                // Scale up: fetch (or lazily build) the next slot's
                // backend, then spawn a worker for it.
                let slot = active;
                let backend = {
                    let mut slots = class.slots.lock().unwrap();
                    match slots.get(slot) {
                        Some(b) => Some(b.clone()), // warm from an earlier grow
                        None => match class.grow.map(|pc| pc.build_replica(slot)) {
                            Some(Ok(b)) => {
                                let r = BackendRef::Shared(b);
                                slots.push(r.clone());
                                Some(r)
                            }
                            Some(Err(e)) => {
                                push_event(
                                    class,
                                    active,
                                    active,
                                    format!("scale-up failed: {e}"),
                                );
                                None
                            }
                            // Not growable (homogeneous path): max ==
                            // base count, so this arm is unreachable —
                            // kept total for safety.
                            None => None,
                        },
                    }
                };
                if let Some(backend) = backend {
                    // Publish the capacity before the worker exists so its
                    // very first retire-token check cannot see a stale
                    // count; the router immediately routes against it. An
                    // RMW (not load+store) so a concurrent count change can
                    // never be silently overwritten.
                    let grown = class.active.fetch_add(1, Ordering::SeqCst) + 1;
                    class.peak.fetch_max(grown, Ordering::Relaxed);
                    push_event(
                        class,
                        grown - 1,
                        grown,
                        if drop_rate > 0.0 {
                            format!("deadline-drop rate {drop_rate:.1}/s in window")
                        } else {
                            format!(
                                "backlog {per_replica:.1}/replica > {:.1}",
                                auto.high_backlog
                            )
                        },
                    );
                    let wid = next_wid.fetch_add(1, Ordering::Relaxed);
                    let queue = if has_router { &class.queue } else { sx.ingress };
                    // A delta-capable replica joins the sticky target
                    // list before its worker runs: streams it serves can
                    // be pinned back to it from its very first batch.
                    let side = sx.sticky.and_then(|sc| {
                        backend.get().supports_delta().then(|| {
                            let q =
                                Arc::new(AdmissionQueue::new(depth, DropPolicy::Block));
                            sc.enroll(wid, ci, &q);
                            q
                        })
                    });
                    s.spawn(move || {
                        let out = worker_loop(
                            wid,
                            ci,
                            class,
                            queue,
                            has_router,
                            backend.get(),
                            side,
                            sx,
                        );
                        outputs_mx.lock().unwrap().push(out);
                    });
                }
            } else if !pressured
                && active > class.min
                && backlog == 0
                && util < auto.low_util
                && span >= auto.window.as_secs_f64() * 0.5
            {
                // Scale down: shrink the advertised capacity first so the
                // router stops counting the leaving replica, then deposit
                // the retire token and wake any parked worker to claim it.
                // RMW for the same reason as scale-up: no lost-update window.
                let shrunk = class.active.fetch_sub(1, Ordering::SeqCst) - 1;
                class.retire.fetch_add(1, Ordering::SeqCst);
                push_event(
                    class,
                    shrunk + 1,
                    shrunk,
                    format!("idle: backlog 0, util {:.0}% < {:.0}%", util * 100.0,
                        auto.low_util * 100.0),
                );
                if has_router {
                    class.queue.wake_consumers();
                } else {
                    sx.ingress.wake_consumers();
                }
            }
        }
    }
}
