//! `SparseMap<T>`: a spatially sparse feature map — the in-memory form of
//! the paper's token-feature stream. Tokens are stored in strictly
//! increasing ravel order; features are a flat `tokens.len() × c` array.

use super::token::{is_strictly_ordered, Token};
use super::Bitmap;

/// Sparse H×W×C feature map. `T` is `f32` for the float path and `i8` for
/// the quantized hardware path.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMap<T> {
    pub w: usize,
    pub h: usize,
    pub c: usize,
    /// Nonzero coordinates, strictly increasing ravel order.
    pub tokens: Vec<Token>,
    /// Row-major per token: `feats[i*c .. (i+1)*c]` is the vector at `tokens[i]`.
    pub feats: Vec<T>,
}

impl<T: Copy + Default + PartialEq> SparseMap<T> {
    pub fn empty(w: usize, h: usize, c: usize) -> Self {
        SparseMap { w, h, c, tokens: Vec::new(), feats: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.tokens.len()
    }

    /// Reset to an empty `w × h × c` map, keeping the token/feature
    /// allocations — the arena-execution path (`model::plan`) resets its
    /// double buffers once per layer, so at steady state this must not
    /// touch the heap.
    pub fn reset(&mut self, w: usize, h: usize, c: usize) {
        self.w = w;
        self.h = h;
        self.c = c;
        self.tokens.clear();
        self.feats.clear();
    }

    /// Copy `src` into `self`, reusing allocations (unlike `Clone::clone`,
    /// which builds fresh vectors).
    pub fn copy_from(&mut self, src: &SparseMap<T>) {
        self.w = src.w;
        self.h = src.h;
        self.c = src.c;
        self.tokens.clear();
        self.tokens.extend_from_slice(&src.tokens);
        self.feats.clear();
        self.feats.extend_from_slice(&src.feats);
    }

    pub fn nz_ratio(&self) -> f64 {
        self.nnz() as f64 / (self.w * self.h) as f64
    }

    /// Feature vector at token index `i`.
    #[inline]
    pub fn feat(&self, i: usize) -> &[T] {
        &self.feats[i * self.c..(i + 1) * self.c]
    }

    /// Append a token + feature vector; enforces stream order in debug.
    pub fn push(&mut self, t: Token, feat: &[T]) {
        debug_assert_eq!(feat.len(), self.c);
        debug_assert!(
            self.tokens.last().map_or(true, |last| last.ravel(self.w) < t.ravel(self.w)),
            "token pushed out of ravel order"
        );
        self.tokens.push(t);
        self.feats.extend_from_slice(feat);
    }

    /// Occupancy bitmap.
    pub fn bitmap(&self) -> Bitmap {
        let mut b = Bitmap::new(self.w, self.h);
        for t in &self.tokens {
            b.set(t.x as usize, t.y as usize);
        }
        b
    }

    /// Validate the Eqn. 1 ordering invariant + shape consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.feats.len() != self.tokens.len() * self.c {
            return Err(format!(
                "feature storage {} != tokens {} × c {}",
                self.feats.len(),
                self.tokens.len(),
                self.c
            ));
        }
        if !is_strictly_ordered(&self.tokens, self.w) {
            return Err("tokens not in strictly increasing ravel order".into());
        }
        if let Some(t) = self
            .tokens
            .iter()
            .find(|t| t.x as usize >= self.w || t.y as usize >= self.h)
        {
            return Err(format!("token ({}, {}) out of {}×{} bounds", t.x, t.y, self.w, self.h));
        }
        Ok(())
    }

    /// Dense `h × w × c` materialization (channel-minor), zeros elsewhere.
    pub fn to_dense(&self) -> Vec<T> {
        let mut out = vec![T::default(); self.h * self.w * self.c];
        for (i, t) in self.tokens.iter().enumerate() {
            let base = (t.y as usize * self.w + t.x as usize) * self.c;
            out[base..base + self.c].copy_from_slice(self.feat(i));
        }
        out
    }

    /// Build from a dense `h × w × c` array, keeping locations where any
    /// channel is non-default (nonzero).
    pub fn from_dense(dense: &[T], w: usize, h: usize, c: usize) -> Self {
        assert_eq!(dense.len(), h * w * c);
        let mut m = SparseMap::empty(w, h, c);
        for y in 0..h {
            for x in 0..w {
                let base = (y * w + x) * c;
                let v = &dense[base..base + c];
                if v.iter().any(|e| *e != T::default()) {
                    m.push(Token::new(x as u16, y as u16), v);
                }
            }
        }
        m
    }

    /// Token index of coordinate `(x, y)` via binary search on ravel order.
    pub fn find(&self, x: u16, y: u16) -> Option<usize> {
        let target = Token::new(x, y).ravel(self.w);
        self.tokens
            .binary_search_by_key(&target, |t| t.ravel(self.w))
            .ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};
    use crate::util::Rng;

    /// Random sparse map generator shared by many test modules.
    pub fn random_map(rng: &mut Rng, w: usize, h: usize, c: usize, p: f64) -> SparseMap<f32> {
        let mut m = SparseMap::empty(w, h, c);
        for y in 0..h {
            for x in 0..w {
                if rng.chance(p) {
                    let f: Vec<f32> = (0..c).map(|_| (rng.f32() - 0.5) * 4.0).collect();
                    // Avoid accidental all-zero vectors (would break
                    // from_dense/to_dense roundtrips).
                    let mut f = f;
                    if f.iter().all(|&v| v == 0.0) {
                        f[0] = 1.0;
                    }
                    m.push(Token::new(x as u16, y as u16), &f);
                }
            }
        }
        m
    }

    #[test]
    fn reset_and_copy_from_reuse_storage() {
        let mut rng = Rng::new(11);
        let src = random_map(&mut rng, 9, 7, 3, 0.4);
        let mut dst: SparseMap<f32> = SparseMap::empty(0, 0, 0);
        dst.copy_from(&src);
        assert_eq!(dst, src);
        let cap_t = dst.tokens.capacity();
        let cap_f = dst.feats.capacity();
        dst.reset(4, 4, 1);
        assert_eq!(dst.nnz(), 0);
        assert_eq!((dst.w, dst.h, dst.c), (4, 4, 1));
        // A same-or-smaller copy after reset keeps the capacities.
        dst.copy_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.tokens.capacity(), cap_t);
        assert_eq!(dst.feats.capacity(), cap_f);
    }

    #[test]
    fn push_and_find() {
        let mut m: SparseMap<f32> = SparseMap::empty(8, 8, 2);
        m.push(Token::new(3, 0), &[1.0, 2.0]);
        m.push(Token::new(1, 2), &[3.0, 4.0]);
        assert_eq!(m.find(3, 0), Some(0));
        assert_eq!(m.find(1, 2), Some(1));
        assert_eq!(m.find(0, 0), None);
        assert_eq!(m.feat(1), &[3.0, 4.0]);
        m.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "ravel order")]
    fn out_of_order_push_panics_in_debug() {
        let mut m: SparseMap<f32> = SparseMap::empty(8, 8, 1);
        m.push(Token::new(5, 5), &[1.0]);
        m.push(Token::new(1, 1), &[1.0]);
    }

    #[test]
    fn dense_roundtrip_property() {
        check("sparse→dense→sparse roundtrip", 128, |g: &mut Gen| {
            let w = g.usize(1, 16);
            let h = g.usize(1, 16);
            let c = g.usize(1, 4);
            let m = random_map(g.rng(), w, h, c, 0.3);
            let d = m.to_dense();
            let back = SparseMap::from_dense(&d, w, h, c);
            assert_eq!(m, back);
        });
    }

    /// The other direction: starting from an arbitrary dense array,
    /// `from_dense ∘ to_dense` is the identity (zeros stay zeros, kept
    /// locations keep their exact feature vectors, and the rebuilt map is
    /// a valid token stream).
    #[test]
    fn dense_first_roundtrip_property() {
        check("dense→sparse→dense roundtrip", 128, |g: &mut Gen| {
            let w = g.usize(1, 12);
            let h = g.usize(1, 12);
            let c = g.usize(1, 4);
            let dense: Vec<f32> = (0..w * h * c)
                .map(|_| if g.chance(0.3) { (g.f64() as f32 - 0.5) * 4.0 } else { 0.0 })
                .collect();
            let m = SparseMap::from_dense(&dense, w, h, c);
            m.validate().unwrap();
            assert_eq!(m.to_dense(), dense);
        });
    }

    #[test]
    fn validate_catches_bad_shapes() {
        let mut m: SparseMap<f32> = SparseMap::empty(4, 4, 2);
        m.tokens.push(Token::new(1, 1));
        assert!(m.validate().is_err()); // missing features
        m.feats.extend_from_slice(&[1.0, 2.0]);
        m.validate().unwrap();
        m.tokens.push(Token::new(9, 0)); // out of bounds AND out of order
        m.feats.extend_from_slice(&[1.0, 2.0]);
        assert!(m.validate().is_err());
    }

    #[test]
    fn bitmap_matches_tokens() {
        let mut rng = Rng::new(77);
        let m = random_map(&mut rng, 12, 9, 3, 0.25);
        let b = m.bitmap();
        assert_eq!(b.count(), m.nnz());
        for t in &m.tokens {
            assert!(b.get(t.x as usize, t.y as usize));
        }
    }
}

#[cfg(test)]
pub use tests::random_map;
