//! Model search — the paper's two-step greedy co-optimization (§3.4.2).
//!
//! 1. Randomly sample MBConv architectures within a parameter budget, with
//!    the total downsampling ratio fixed per dataset ([`space`]).
//! 2. Push every sample through the Eqn. 6 hardware optimizer; keep the
//!    top-k by estimated throughput; score those for accuracy and pick the
//!    best ([`search`]).
//!
//! The paper trains the top-k candidates with MinkowskiEngine; here the
//! accuracy scoring is a **linear-probe proxy** (random-feature network +
//! trained softmax head on the synthetic dataset — documented substitution,
//! DESIGN.md §2). The full float training lives in the python path; the
//! exported accuracies of the final models come from there.
pub mod space;
pub mod search;

pub use search::{search, Candidate, SearchConfig};
pub use space::{sample_network, SearchSpace};
