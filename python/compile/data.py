"""Reader for the rust-generated event datasets plus the histogram
representation (mirror of ``rust/src/events/repr.rs::histogram2_norm``).

The datasets are produced by ``esda gen-data`` (see ``rust/src/events``) so
training and hardware simulation consume byte-identical inputs. Container
layout documented in ``rust/src/events/io.rs``.
"""

import struct

import numpy as np

MAGIC = 0x4553_4441
VERSION = 1


def read_dataset(path):
    """Returns (w, h, samples) with samples = list of (label, events);
    events is a structured numpy array (t, x, y, p)."""
    with open(path, "rb") as f:
        magic, version, w, h, n = struct.unpack("<IIIII", f.read(20))
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic:#x} in {path}")
        if version != VERSION:
            raise ValueError(f"unsupported version {version}")
        ev_dtype = np.dtype([("t", "<u4"), ("x", "<u2"), ("y", "<u2"), ("p", "u1"), ("_pad", "u1")])
        samples = []
        for _ in range(n):
            label, ne = struct.unpack("<II", f.read(8))
            events = np.frombuffer(f.read(ne * ev_dtype.itemsize), dtype=ev_dtype)
            samples.append((label, events))
    return w, h, samples


def histogram2_norm(events, w, h, clip=8.0):
    """2-channel event histogram, clipped and scaled to [0, 1] — mirror of
    the rust representation builder (channel 0 = ON, 1 = OFF)."""
    out = np.zeros((h, w, 2), dtype=np.float32)
    if len(events):
        pol = events["p"].astype(np.int64)
        np.add.at(out, (events["y"].astype(np.int64), events["x"].astype(np.int64), 1 - pol), 1.0)
    return np.minimum(out, clip) / clip


def load_split(path, clip=8.0):
    """Dataset file → (X: (N, H, W, 2) f32, y: (N,) i32)."""
    w, h, samples = read_dataset(path)
    xs = np.stack([histogram2_norm(ev, w, h, clip) for _, ev in samples])
    ys = np.array([label for label, _ in samples], dtype=np.int32)
    return xs, ys
